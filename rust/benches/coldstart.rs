//! Cold-start bench: what does it cost to get N models *runnable* in a
//! fresh process?  Three paths, same netlists (EXPERIMENTS.md §Cold
//! start):
//!
//! * **recompile** — the pre-artifact world: plans compiled from the
//!   in-memory netlists (bit-plane decomposition, support extraction,
//!   table interning — all redone every process start);
//! * **plan image** — `load_nlb` on exported `.nlb` artifacts carrying
//!   compiled-plan images (read + checksum + full validation, no
//!   compilation);
//! * **plan cache** — a fresh `PlanCache::persistent` instance over a
//!   warm cache directory (the restarted-server path; must serve every
//!   plan from disk, asserted via `disk_hits`).
//!
//! Every artifact-loaded plan is also run through the engine
//! `check_conformance` suite against its own netlist — the bench
//! doubles as the CI cold-start smoke (`-- --quick` skips the timing
//! floors, never the conformance).  Writes `BENCH_coldstart.json`.
//! (`cargo bench --bench coldstart`)

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use neuralut::coordinator::check_conformance;
use neuralut::netlist::testutil::random_reducible_netlist;
use neuralut::netlist::{compile, load_nlb, save_nlb, Netlist, PlanCache,
                        PlanExecutor, PlanOptions};
use neuralut::report::Table;
use neuralut::util::Json;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn bench<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    median(times)
}

/// N structurally distinct jsc-shaped reducible netlists (per-bit
/// support <= 6, the structure trained tables have) with unique
/// content hashes.
fn model_fleet(n: usize) -> Vec<Netlist> {
    (0..n)
        .map(|i| {
            let mut nl = random_reducible_netlist(
                1000 + i as u64, 16, 4,
                &[(80, 2, 4), (40, 2, 4), (20, 2, 4), (10, 2, 4),
                  (5, 2, 8)],
                6);
            nl.name = format!("fleet{i}");
            nl
        })
        .collect()
}

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("nla_coldstart_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 2 } else { 7 };
    if quick {
        println!("--quick: minimal reps, timing floors skipped \
                  (conformance still enforced)");
    }
    let n_total = 16usize;
    let fleet = model_fleet(n_total);
    let opts = PlanOptions::default();

    // export the whole fleet once: .nlb with plan images
    let art_dir = temp_dir("artifacts");
    let paths: Vec<PathBuf> = fleet
        .iter()
        .map(|nl| {
            let p = art_dir.join(format!("{}.nlb", nl.name));
            let plan = compile(nl, opts);
            save_nlb(&p, nl, Some(&plan)).unwrap();
            p
        })
        .collect();

    // warm plan-cache directory (what a prior server run leaves behind)
    let cache_dir = temp_dir("plancache");
    {
        let warm = PlanCache::persistent(&cache_dir);
        for nl in &fleet {
            warm.get_or_compile(nl, opts);
        }
        assert_eq!(warm.misses(), n_total as u64,
                   "warming must compile every model once");
    }

    let mut table = Table::new(
        "cold start: N models runnable in a fresh process",
        &["path", "N", "median total", "per model"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut record = |table: &mut Table, rows: &mut Vec<Json>, case: &str,
                      n: usize, secs: f64| {
        table.row(&[
            case.into(),
            n.to_string(),
            format!("{:.2} ms", secs * 1e3),
            format!("{:.1} us", secs * 1e6 / n as f64),
        ]);
        let mut obj = BTreeMap::new();
        obj.insert("case".into(), Json::Str(case.into()));
        obj.insert("n_models".into(), Json::Num(n as f64));
        obj.insert("ms".into(), Json::Num(secs * 1e3));
        obj.insert("us_per_model".into(),
                   Json::Num(secs * 1e6 / n as f64));
        rows.push(Json::Obj(obj));
    };

    let mut compile_at = BTreeMap::new();
    let mut load_at = BTreeMap::new();
    let mut cache_at = BTreeMap::new();
    for n in [1usize, 8, n_total] {
        let t_compile = bench(reps, || {
            for nl in &fleet[..n] {
                std::hint::black_box(compile(nl, opts));
            }
        });
        record(&mut table, &mut rows, "recompile from netlist", n,
               t_compile);
        let t_load = bench(reps, || {
            for p in &paths[..n] {
                let m = load_nlb(p).unwrap();
                assert!(m.plan.is_some());
                std::hint::black_box(&m);
            }
        });
        record(&mut table, &mut rows, "load .nlb plan image", n, t_load);
        let t_cache = bench(reps, || {
            let cache = PlanCache::persistent(&cache_dir);
            for nl in &fleet[..n] {
                std::hint::black_box(cache.get_or_compile(nl, opts));
            }
            assert_eq!(cache.disk_hits(), n as u64,
                       "every plan must come from the warm disk cache");
        });
        record(&mut table, &mut rows, "persistent plan cache (warm)", n,
               t_cache);
        compile_at.insert(n, t_compile);
        load_at.insert(n, t_load);
        cache_at.insert(n, t_cache);
    }

    // conformance: every artifact-loaded plan must satisfy the engine
    // contract against its own netlist — this is the CI smoke payload
    for (i, p) in paths.iter().enumerate() {
        let m = load_nlb(p).unwrap();
        let plan = m.plan.clone().expect("artifact carries a plan image");
        let mut ex = PlanExecutor::new(plan);
        check_conformance(&mut ex, &m.netlist, 0xC0 + i as u64)
            .unwrap_or_else(|e| panic!("model {i}: {e:#}"));
    }
    println!("conformance: {} artifact-loaded plans pass the engine \
              contract", paths.len());

    table.print();
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("coldstart".into()));
    root.insert("quick".into(), Json::Bool(quick));
    root.insert("reps".into(), Json::Num(reps as f64));
    root.insert("n_models".into(), Json::Num(n_total as f64));
    root.insert("rows".into(), Json::Arr(rows));
    let path = "BENCH_coldstart.json";
    match std::fs::write(path, Json::Obj(root).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    for n in [8usize, n_total] {
        println!("@ {n} models: plan-image load {:.2}x vs recompile, \
                  warm cache {:.2}x vs recompile",
                 compile_at[&n] / load_at[&n],
                 compile_at[&n] / cache_at[&n]);
    }

    let _ = std::fs::remove_dir_all(&art_dir);
    let _ = std::fs::remove_dir_all(&cache_dir);
    if quick {
        println!("(--quick: timing floors not enforced this run)");
        return;
    }
    // the acceptance floor: at >= 8 registered models both artifact
    // paths must beat recompilation outright — skipping bit-plane
    // decomposition and table interning is an algorithmic win, not a
    // constant-factor one, so no noise slack is granted
    for n in [8usize, n_total] {
        assert!(load_at[&n] < compile_at[&n],
                "@ {n} models: plan-image load {:.2}ms not faster than \
                 recompile {:.2}ms",
                load_at[&n] * 1e3, compile_at[&n] * 1e3);
        assert!(cache_at[&n] < compile_at[&n],
                "@ {n} models: warm plan cache {:.2}ms not faster than \
                 recompile {:.2}ms",
                cache_at[&n] * 1e3, compile_at[&n] * 1e3);
    }
}
