//! Offline stub of the `xla` PJRT bindings (the subset of xla-rs, pinned
//! against xla_extension 0.5.1, that `neuralut::runtime` uses).
//!
//! [`Literal`] is a fully functional host tensor (f32/i32 arrays plus
//! tuples), so everything that only moves data — `lit_f32`, `ParamStore`,
//! snapshots — works unchanged.  Everything that needs the native
//! xla_extension library (HLO parsing, compilation, execution) returns
//! [`Error::unavailable`]; callers see a clean `anyhow` error chain
//! ("xla_extension not available in this build") instead of a link
//! failure, and the rest of the crate — simulator, mapper, timing, RTL,
//! server — stays fully usable.  Point the `xla` dependency in the root
//! Cargo.toml at the real bindings to enable the PJRT flow.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring xla-rs: displayable, `std::error::Error`, and
/// convertible into `anyhow::Error` via `?`.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: xla_extension not available in this build \
             (vendored stub; see rust/vendor/xla-stub)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Array shape (dims in i64, xla convention).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Shape of a literal: array or tuple.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

#[derive(Clone, Debug, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host tensor: element data plus dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Element types the stub ferries (the crate only uses f32 and i32).
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Option<&[f32]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }
    fn unwrap(data: &Data) -> Option<&[i32]> {
        match data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::wrap(vec![v]), dims: Vec::new() }
    }

    /// Rank-1 literal.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    /// Tuple literal (what executions return).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { data: Data::Tuple(elems), dims: Vec::new() }
    }

    fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Same data, new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error("reshape on a tuple literal".into()));
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}", self.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out the elements (type must match the stored element type).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            _ => Err(Error("to_tuple on a non-tuple literal".into())),
        }
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(match &self.data {
            Data::Tuple(v) => Shape::Tuple(
                v.iter()
                    .map(|l| l.shape())
                    .collect::<Result<Vec<_>>>()?,
            ),
            _ => Shape::Array(ArrayShape { dims: self.dims.clone() }),
        })
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!(
            "parsing HLO text {:?}", path.as_ref()
        )))
    }
}

/// Computation wrapper (constructible, but compilation always fails).
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device buffer handle returned by executions.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("fetching buffer"))
    }
}

/// Compiled executable (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("executing"))
    }
}

/// PJRT client.  `cpu()` succeeds so hosts can construct a `Runtime` and
/// report the (stubbed) platform; loading/compiling artifacts is what
/// fails, with a message naming this stub.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu (xla_extension unavailable)".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compiling"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        match r.shape().unwrap() {
            Shape::Array(a) => assert_eq!(a.dims(), &[2, 2]),
            _ => panic!("not an array"),
        }
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![Literal::scalar(1i32),
                                    Literal::scalar(2.0f32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<i32>().unwrap(), vec![1]);
    }

    #[test]
    fn native_paths_unavailable() {
        assert!(HloModuleProto::from_text_file("/nope.hlo").is_err());
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
    }
}
