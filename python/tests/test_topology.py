"""Topology validation tests: presets, assemble constraints, helpers."""

import dataclasses

import pytest

from compile import model as M
from compile.topology import Topology, preset, presets


def test_all_presets_validate():
    ps = presets()
    assert {p.name for p in ps} >= {
        "mnist", "jsc_cb", "jsc_oml", "nid",
        "fig5_opt1", "fig5_opt2", "fig5_opt3"}
    for p in ps:
        p.validate()


def test_assemble_ratio_enforced():
    t = preset("mnist")
    bad = dataclasses.replace(t, w=[360, 61, 10])
    with pytest.raises(ValueError):
        bad.validate()


def test_layer0_cannot_assemble():
    t = preset("mnist")
    bad = dataclasses.replace(t, a=[1, 1, 1])
    with pytest.raises(ValueError):
        bad.validate()


def test_table_cap_enforced():
    t = preset("jsc_cb")
    bad = dataclasses.replace(t, beta=[4, 4, 4, 4, 8], F=[16, 2, 2, 2, 2])
    with pytest.raises(ValueError):
        bad.validate()


def test_table_entries():
    t = preset("nid")
    # layer0: beta_in=1, F=6 -> 64 entries; layer1: beta=2, F=3 -> 64
    assert t.table_entries(0) == 64
    assert t.table_entries(1) == 64


def test_fixed_connections_strided():
    t = preset("mnist")
    conns = t.fixed_connections(1)
    assert len(conns) == 60
    assert conns[0] == [0, 1, 2, 3, 4, 5]
    assert conns[59] == [354, 355, 356, 357, 358, 359]


def test_relu_flags_tree_runs():
    # mnist a=[0,1,1]: single run ending at output -> no output relu anywhere
    assert M.relu_flags(preset("mnist")) == [False, False, False]
    # nid a=[0,1,0,1,1]: runs {0,1},{2,3,4}; relu at layer1 only
    assert M.relu_flags(preset("nid")) == [False, True, False, False, False]


def test_param_spec_shapes():
    t = preset("nid")
    spec = dict(M.param_spec(t, dense=False))
    assert spec["l0_W0"] == (60, 6, 16)
    assert spec["l0_Wh"] == (1, 60, 16, 16)
    assert spec["l2_wskip"] == (9, 3)
    assert spec["l4_bout"] == (1,)
    dense = dict(M.param_spec(t, dense=True))
    assert dense["l0_W0"] == (60, 593, 16)   # learned layer densified
    assert dense["l1_W0"] == (20, 3, 16)     # assemble layer unchanged
    assert dense["l2_wskip"] == (9, 20)


def test_fig5_tree_shapes():
    o1, o2, o3 = preset("fig5_opt1"), preset("fig5_opt2"), preset("fig5_opt3")
    # 5 trees (one per class), 16 inputs each
    assert o1.w == [20, 5] and o1.F[0] == 4
    assert o2.w[0] * o2.F[0] // o2.w[-1] // 2 ** (len(o2.w) - 1)  # shape holds
    assert o3.w[0] * o3.F[0] == 320  # 64 inputs x 5 trees
