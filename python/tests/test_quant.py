"""Quantizer unit tests: code/value round-trips, STE, edge cases."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import quant


@pytest.mark.parametrize("beta", [1, 2, 3, 4, 6, 8])
def test_encode_range(beta):
    x = jnp.linspace(-3.0, 3.0, 1001)
    c = quant.encode(x, 1.0, beta)
    assert int(c.min()) >= 0
    assert int(c.max()) <= (1 << beta) - 1
    # extremes saturate
    assert int(quant.encode(jnp.array([-10.0]), 1.0, beta)[0]) == 0
    assert int(quant.encode(jnp.array([10.0]), 1.0, beta)[0]) == (1 << beta) - 1


@pytest.mark.parametrize("beta", [1, 2, 4, 6])
def test_decode_midrise_symmetric(beta):
    codes = jnp.arange(1 << beta, dtype=jnp.int32)
    v = np.asarray(quant.decode(codes, 1.0, beta))
    # midrise: values symmetric about 0, none exactly 0
    np.testing.assert_allclose(v, -v[::-1], atol=1e-7)
    assert np.all(np.abs(v) > 0)
    assert np.all(np.diff(v) > 0)


@pytest.mark.parametrize("beta", [1, 2, 4])
@pytest.mark.parametrize("s", [0.5, 1.0, 2.0])
def test_roundtrip_bin_centers(beta, s):
    codes = jnp.arange(1 << beta, dtype=jnp.int32)
    v = quant.decode(codes, s, beta)
    c2 = quant.encode(v, s, beta)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(c2))


def test_reconstruct_matches_encode_decode():
    x = jnp.linspace(-2.0, 2.0, 257)
    for beta in (1, 3, 6):
        a = quant.reconstruct(x, 1.3, beta)
        b = quant.decode(quant.encode(x, 1.3, beta), 1.3, beta)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fake_quant_forward_value():
    x = jnp.linspace(-2.0, 2.0, 101)
    for beta in (1, 2, 4):
        fq = quant.fake_quant(x, 1.0, beta)
        rec = quant.reconstruct(x, 1.0, beta)
        np.testing.assert_allclose(np.asarray(fq), np.asarray(rec), atol=1e-7)


def test_fake_quant_ste_gradient():
    # gradient w.r.t. x is 1 inside the clip range, 0 outside
    g = jax.grad(lambda x: jnp.sum(quant.fake_quant(x, 1.0, 4)))
    x = jnp.array([-2.0, -0.5, 0.0, 0.5, 2.0])
    gx = np.asarray(g(x))
    np.testing.assert_allclose(gx, [0.0, 1.0, 1.0, 1.0, 0.0], atol=1e-6)


def test_fake_quant_scale_gradient_nonzero():
    g = jax.grad(lambda s: jnp.sum(quant.fake_quant(
        jnp.linspace(-2, 2, 64), s, 3)))
    assert abs(float(g(1.0))) > 0.0
