"""NLWP wire-protocol tests: canonical round-trips, totality under
corruption (truncations, bit flips, hostile length prefixes), the
fatal/recoverable split, and the committed golden frames staying in
sync with the encoder (the rust ``net`` suite holds the other end of
that contract)."""

import os
import struct

import pytest

from compile import wire

import golden_wire


SAMPLES = golden_wire.golden_frames()


def test_roundtrip_every_kind_is_canonical():
    for frame_id, msg in SAMPLES:
        data = wire.encode_frame(frame_id, msg)
        frame, used = wire.decode_frame(data)
        assert used == len(data)
        assert frame.id == frame_id
        assert frame.msg == msg
        # re-encoding the decoded frame is byte-identical
        assert wire.encode_frame(frame.id, frame.msg) == data


def test_rejects_truncation_at_every_length():
    data = wire.encode_frame(
        3, wire.Infer(model="m", batch=2, n_in=2, codes=[1, 2, 3, 4]))
    for n in range(len(data)):
        with pytest.raises(wire.WireError):
            wire.decode_frame(data[:n])


def test_single_byte_body_corruption_is_always_caught():
    data = bytearray(wire.encode_frame(
        4, wire.Infer(model="model", batch=3, n_in=4,
                      codes=list(range(12)))))
    for pos in range(wire.HEADER_LEN, len(data)):
        for flip in (0x01, 0x80, 0xFF):
            evil = bytearray(data)
            evil[pos] ^= flip
            with pytest.raises(wire.WireError) as e:
                wire.decode_frame(bytes(evil))
            assert "checksum" in str(e.value), (pos, flip)


def test_bad_magic_and_version_and_oversize_are_fatal():
    base = wire.encode_frame(5, wire.Ping())

    evil = b"X" + base[1:]
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(evil)
    assert e.value.fatal and "magic" in str(e.value)

    evil = bytearray(base)
    evil[4] = wire.WIRE_VERSION + 1
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(bytes(evil))
    assert e.value.fatal and "version" in str(e.value)

    evil = bytearray(base)
    evil[16:20] = struct.pack("<I", 0xFFFFFFFF)
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(bytes(evil))
    assert e.value.fatal and "cap" in str(e.value)


def test_unknown_kind_and_checksum_are_recoverable():
    base = bytearray(wire.encode_frame(5, wire.Ping()))
    base[6] = 0xEE
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(bytes(base))
    assert not e.value.fatal and "unknown frame kind" in str(e.value)

    data = bytearray(wire.encode_frame(
        6, wire.Stats(model="m")))
    data[-1] ^= 0x40
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(bytes(data))
    assert not e.value.fatal


def test_rejects_overlong_name_with_consistent_checksum():
    body = struct.pack("<H", wire.MAX_NAME + 1)
    body += b"a" * (wire.MAX_NAME + 1)
    body += struct.pack("<II", 1, 0)
    data = wire.WIRE_MAGIC + struct.pack(
        "<HHQII", wire.WIRE_VERSION, wire.KIND_INFER, 1, len(body),
        wire.fnv1a(body) & 0xFFFFFFFF)
    data += body
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(data)
    assert not e.value.fatal and "cap" in str(e.value)


def test_rejects_trailing_bytes_in_body():
    body = b"\x55"
    data = wire.WIRE_MAGIC + struct.pack(
        "<HHQII", wire.WIRE_VERSION, wire.KIND_PING, 6, len(body),
        wire.fnv1a(body) & 0xFFFFFFFF)
    data += body
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(data)
    assert "trailing" in str(e.value)


def test_error_message_truncates_at_char_boundary():
    long = "é" * wire.MAX_MESSAGE  # 2 bytes per char
    data = wire.encode_frame(
        1, wire.Error(code=wire.ERR_INTERNAL, message=long))
    frame, _ = wire.decode_frame(data)
    assert len(frame.msg.message.encode("utf-8")) <= wire.MAX_MESSAGE
    assert frame.msg.message  # non-empty, valid UTF-8 by construction


def test_back_to_back_frames_parse_from_one_buffer():
    stream = golden_wire.golden_bytes()
    offset = 0
    for frame_id, msg in SAMPLES:
        frame, used = wire.decode_frame(stream[offset:])
        assert frame.id == frame_id
        assert frame.msg == msg
        offset += used
    assert offset == len(stream)


def test_committed_golden_frames_match_encoder():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "rust",
                        "tests", "golden", "golden_frames.bin")
    with open(path, "rb") as f:
        committed = f.read()
    assert committed == golden_wire.golden_bytes(), (
        "rust/tests/golden/golden_frames.bin is stale — regenerate with "
        "`python -m tests.golden_wire` and update the rust expectations")


def test_committed_v1_golden_frames_match_v1_encoder():
    # the v1 stream is pinned forever: old peers must keep working
    path = os.path.join(os.path.dirname(__file__), "..", "..", "rust",
                        "tests", "golden", "golden_frames_v1.bin")
    with open(path, "rb") as f:
        committed = f.read()
    assert committed == golden_wire.golden_bytes_v1(), (
        "rust/tests/golden/golden_frames_v1.bin changed — the v1 "
        "encoding is frozen and must never drift")


def test_v2_reader_decodes_v1_frames_as_no_deadline():
    offset = 0
    stream = golden_wire.golden_bytes_v1()
    for frame_id, msg in golden_wire.golden_frames_v1():
        frame, used = wire.decode_frame(stream[offset:])
        assert frame.id == frame_id
        assert frame.msg == msg
        if isinstance(frame.msg, wire.Infer):
            assert frame.msg.deadline_us is None
        # canonical per version: the v1 encoder reproduces the bytes
        assert wire.encode_frame(frame.id, frame.msg, version=1) == \
            stream[offset:offset + used]
        offset += used
    assert offset == len(stream)


def test_v1_encoder_refuses_to_drop_a_deadline():
    msg = wire.Infer(model="m", batch=1, n_in=1, codes=[0],
                     deadline_us=1000)
    with pytest.raises(AssertionError):
        wire.encode_frame(1, msg, version=1)


def _with_raw_deadline(data: bytes, model: str, raw: int) -> bytes:
    """Rewrite the raw deadline field of an encoded v2 INFER frame and
    fix the checksum, to forge semantically-hostile-but-valid bytes."""
    off = wire.HEADER_LEN + 2 + len(model.encode()) + 4 + 4
    evil = bytearray(data)
    evil[off:off + 8] = struct.pack("<Q", raw)
    body = bytes(evil[wire.HEADER_LEN:])
    evil[20:24] = struct.pack("<I", wire.fnv1a(body) & 0xFFFFFFFF)
    return bytes(evil)


def test_deadline_validation_rejects_zero_and_oversize():
    good = wire.encode_frame(
        9, wire.Infer(model="m", batch=1, n_in=2, codes=[5, -5],
                      deadline_us=1000))
    # boundary values survive
    for raw in (1, wire.MAX_DEADLINE_US):
        frame, _ = wire.decode_frame(_with_raw_deadline(good, "m", raw))
        assert frame.msg.deadline_us == raw
    # the sentinel decodes as "no deadline"
    frame, _ = wire.decode_frame(
        _with_raw_deadline(good, "m", wire.NO_DEADLINE))
    assert frame.msg.deadline_us is None
    # zero and oversize are malformed (recoverable, not fatal)
    for raw in (0, wire.MAX_DEADLINE_US + 1):
        with pytest.raises(wire.WireError) as e:
            wire.decode_frame(_with_raw_deadline(good, "m", raw))
        assert not e.value.fatal
        assert "deadline" in str(e.value)


def test_version_zero_and_future_versions_are_fatal():
    base = bytearray(wire.encode_frame(5, wire.Ping()))
    for v in (0, wire.WIRE_VERSION + 1, 0xFFFF):
        evil = bytearray(base)
        evil[4:6] = struct.pack("<H", v)
        with pytest.raises(wire.WireError) as e:
            wire.decode_frame(bytes(evil))
        assert e.value.fatal and "version" in str(e.value)


def test_deadline_roundtrips_canonically():
    for dl in (None, 1, 250_000, wire.MAX_DEADLINE_US):
        msg = wire.Infer(model="m", batch=2, n_in=1, codes=[3, 4],
                         deadline_us=dl)
        data = wire.encode_frame(42, msg)
        frame, used = wire.decode_frame(data)
        assert used == len(data)
        assert frame.msg == msg
        assert wire.encode_frame(frame.id, frame.msg) == data
