"""NLWP wire-protocol tests: canonical round-trips, totality under
corruption (truncations, bit flips, hostile length prefixes), the
fatal/recoverable split, and the committed golden frames staying in
sync with the encoder (the rust ``net`` suite holds the other end of
that contract)."""

import os
import struct

import pytest

from compile import wire

import golden_wire


SAMPLES = golden_wire.golden_frames()


def test_roundtrip_every_kind_is_canonical():
    for frame_id, msg in SAMPLES:
        data = wire.encode_frame(frame_id, msg)
        frame, used = wire.decode_frame(data)
        assert used == len(data)
        assert frame.id == frame_id
        assert frame.msg == msg
        # re-encoding the decoded frame is byte-identical
        assert wire.encode_frame(frame.id, frame.msg) == data


def test_rejects_truncation_at_every_length():
    data = wire.encode_frame(
        3, wire.Infer(model="m", batch=2, n_in=2, codes=[1, 2, 3, 4]))
    for n in range(len(data)):
        with pytest.raises(wire.WireError):
            wire.decode_frame(data[:n])


def test_single_byte_body_corruption_is_always_caught():
    data = bytearray(wire.encode_frame(
        4, wire.Infer(model="model", batch=3, n_in=4,
                      codes=list(range(12)))))
    for pos in range(wire.HEADER_LEN, len(data)):
        for flip in (0x01, 0x80, 0xFF):
            evil = bytearray(data)
            evil[pos] ^= flip
            with pytest.raises(wire.WireError) as e:
                wire.decode_frame(bytes(evil))
            assert "checksum" in str(e.value), (pos, flip)


def test_bad_magic_and_version_and_oversize_are_fatal():
    base = wire.encode_frame(5, wire.Ping())

    evil = b"X" + base[1:]
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(evil)
    assert e.value.fatal and "magic" in str(e.value)

    evil = bytearray(base)
    evil[4] = wire.WIRE_VERSION + 1
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(bytes(evil))
    assert e.value.fatal and "version" in str(e.value)

    evil = bytearray(base)
    evil[16:20] = struct.pack("<I", 0xFFFFFFFF)
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(bytes(evil))
    assert e.value.fatal and "cap" in str(e.value)


def test_unknown_kind_and_checksum_are_recoverable():
    base = bytearray(wire.encode_frame(5, wire.Ping()))
    base[6] = 0xEE
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(bytes(base))
    assert not e.value.fatal and "unknown frame kind" in str(e.value)

    data = bytearray(wire.encode_frame(
        6, wire.Stats(model="m")))
    data[-1] ^= 0x40
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(bytes(data))
    assert not e.value.fatal


def test_rejects_overlong_name_with_consistent_checksum():
    body = struct.pack("<H", wire.MAX_NAME + 1)
    body += b"a" * (wire.MAX_NAME + 1)
    body += struct.pack("<II", 1, 0)
    data = wire.WIRE_MAGIC + struct.pack(
        "<HHQII", wire.WIRE_VERSION, wire.KIND_INFER, 1, len(body),
        wire.fnv1a(body) & 0xFFFFFFFF)
    data += body
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(data)
    assert not e.value.fatal and "cap" in str(e.value)


def test_rejects_trailing_bytes_in_body():
    body = b"\x55"
    data = wire.WIRE_MAGIC + struct.pack(
        "<HHQII", wire.WIRE_VERSION, wire.KIND_PING, 6, len(body),
        wire.fnv1a(body) & 0xFFFFFFFF)
    data += body
    with pytest.raises(wire.WireError) as e:
        wire.decode_frame(data)
    assert "trailing" in str(e.value)


def test_error_message_truncates_at_char_boundary():
    long = "é" * wire.MAX_MESSAGE  # 2 bytes per char
    data = wire.encode_frame(
        1, wire.Error(code=wire.ERR_INTERNAL, message=long))
    frame, _ = wire.decode_frame(data)
    assert len(frame.msg.message.encode("utf-8")) <= wire.MAX_MESSAGE
    assert frame.msg.message  # non-empty, valid UTF-8 by construction


def test_back_to_back_frames_parse_from_one_buffer():
    stream = golden_wire.golden_bytes()
    offset = 0
    for frame_id, msg in SAMPLES:
        frame, used = wire.decode_frame(stream[offset:])
        assert frame.id == frame_id
        assert frame.msg == msg
        offset += used
    assert offset == len(stream)


def test_committed_golden_frames_match_encoder():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "rust",
                        "tests", "golden", "golden_frames.bin")
    with open(path, "rb") as f:
        committed = f.read()
    assert committed == golden_wire.golden_bytes(), (
        "rust/tests/golden/golden_frames.bin is stale — regenerate with "
        "`python -m tests.golden_wire` and update the rust expectations")
