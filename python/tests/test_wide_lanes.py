"""Bit-exact python port of the wide-word (lane) bit-plane kernel.

The rust executor (``rust/src/netlist/plan.rs``) evaluates each
support-reduced plane as a Shannon mux-tree over packed u64 words —
64 samples per word — and the wide backend runs the *same* recursion
over lanes of W consecutive words, with ragged tails (``nwords % W``)
falling through to the scalar recursion.  This file ports both paths
to pure python (no jax, no numpy) and proves the algorithm:

* the scalar recursion implements per-sample table lookup exactly;
* the lane recursion at W in {1, 4, 8} is bit-identical to the scalar
  recursion on every word, for ragged word counts on both sides of a
  lane-block boundary;
* the blocked+tail plane kernel (the loop structure the rust code
  runs) matches the all-scalar plane evaluation word for word.

The container has no cargo, so this is the local executable witness
for the widening; the rust property suite (``tests/properties.rs``,
``prop_wide_executor_is_bit_exact``) holds the same contract end to
end in CI.
"""

import random

import pytest

MASK64 = (1 << 64) - 1
MAX_PLANE_SUPPORT = 6


def eval_packed_rec(table, inputs):
    """Scalar Shannon recursion: one u64 word per input plane."""
    if not inputs:
        return MASK64 if table & 1 else 0
    x = inputs[-1]
    half = 1 << (len(inputs) - 1)
    mask = (1 << half) - 1
    lo = eval_packed_rec(table & mask, inputs[:-1])
    hi = eval_packed_rec((table >> half) & mask, inputs[:-1])
    return ((~x & MASK64) & lo) | (x & hi)


def eval_packed_lanes(table, lanes, w):
    """Lane recursion: each input is a list of W u64 words, and every
    bitwise op acts elementwise — the shape the compiler vectorizes."""
    if not lanes:
        v = MASK64 if table & 1 else 0
        return [v] * w
    x = lanes[-1]
    half = 1 << (len(lanes) - 1)
    mask = (1 << half) - 1
    lo = eval_packed_lanes(table & mask, lanes[:-1], w)
    hi = eval_packed_lanes((table >> half) & mask, lanes[:-1], w)
    return [((~xi & MASK64) & lo_i) | (xi & hi_i)
            for xi, lo_i, hi_i in zip(x, lo, hi)]


def plane_scalar(table, srcs, prev, nwords):
    """Reference: every word of one output plane via the scalar path."""
    return [eval_packed_rec(table, [prev[s][wd] for s in srcs])
            for wd in range(nwords)]


def plane_wide(table, srcs, prev, nwords, w):
    """The rust loop structure: full lane blocks, then a scalar tail."""
    out = [0] * nwords
    blocks = nwords // w
    for blk in range(blocks):
        wd = blk * w
        lanes = [prev[s][wd:wd + w] for s in srcs]
        out[wd:wd + w] = eval_packed_lanes(table, lanes, w)
    for wd in range(blocks * w, nwords):
        out[wd] = eval_packed_rec(table, [prev[s][wd] for s in srcs])
    return out


def random_plane_words(rng, nwords):
    return [rng.getrandbits(64) for _ in range(nwords)]


@pytest.mark.parametrize("arity", range(4))
def test_scalar_recursion_is_per_sample_table_lookup(arity):
    # the ground truth the whole stack rests on: bit b of the packed
    # result is table[address assembled from bit b of each input]
    rng = random.Random(0xA0 + arity)
    table = rng.getrandbits(1 << arity) if arity else rng.getrandbits(1)
    inputs = [rng.getrandbits(64) for _ in range(arity)]
    packed = eval_packed_rec(table, inputs)
    for b in range(64):
        addr = 0
        for i, word in enumerate(inputs):
            addr |= ((word >> b) & 1) << i
        want = (table >> addr) & 1
        assert (packed >> b) & 1 == want, f"sample {b}"


@pytest.mark.parametrize("w", [1, 4, 8])
@pytest.mark.parametrize("arity", range(MAX_PLANE_SUPPORT + 1))
def test_lane_recursion_matches_scalar_wordwise(w, arity):
    rng = random.Random(w * 31 + arity)
    for _ in range(16):
        table = rng.getrandbits(1 << arity)
        lanes = [[rng.getrandbits(64) for _ in range(w)]
                 for _ in range(arity)]
        wide = eval_packed_lanes(table, lanes, w)
        for i in range(w):
            want = eval_packed_rec(table, [lane[i] for lane in lanes])
            assert wide[i] == want, f"lane word {i}"


@pytest.mark.parametrize("w", [1, 4, 8])
def test_constant_plane_splats_into_every_lane_word(w):
    # arity 0 (a constant output bit after support reduction) must
    # splat all-ones or all-zeros across the full lane
    assert eval_packed_lanes(1, [], w) == [MASK64] * w
    assert eval_packed_lanes(0, [], w) == [0] * w
    assert eval_packed_rec(1, []) == MASK64
    assert eval_packed_rec(0, []) == 0


@pytest.mark.parametrize("w", [1, 4, 8])
@pytest.mark.parametrize(
    "nwords", [1, 3, 4, 5, 7, 8, 9, 11, 16, 24, 25, 31, 33])
def test_blocked_plane_kernel_matches_scalar_on_ragged_words(w, nwords):
    # nwords on both sides of every lane-block boundary: below one
    # block (pure tail), exact multiples (no tail), and blocks + tail.
    # batch sizes 1..=3*64*W in the rust suite land on exactly these
    # word counts.
    rng = random.Random(w * 1000 + nwords)
    n_planes = 8
    prev = [random_plane_words(rng, nwords) for _ in range(n_planes)]
    for arity in range(MAX_PLANE_SUPPORT + 1):
        table = rng.getrandbits(1 << arity)
        srcs = [rng.randrange(n_planes) for _ in range(arity)]
        want = plane_scalar(table, srcs, prev, nwords)
        got = plane_wide(table, srcs, prev, nwords, w)
        assert got == want, f"arity {arity}"


def test_w1_wide_path_is_the_scalar_path():
    # the W=1 "wide" executor is the scalar reference by construction:
    # one-word lanes must reproduce the scalar recursion verbatim
    rng = random.Random(7)
    for _ in range(64):
        arity = rng.randrange(MAX_PLANE_SUPPORT + 1)
        table = rng.getrandbits(1 << arity)
        inputs = [rng.getrandbits(64) for _ in range(arity)]
        lanes = [[word] for word in inputs]
        assert eval_packed_lanes(table, lanes, 1) == \
            [eval_packed_rec(table, inputs)]


def test_shared_source_plane_aliasing_is_safe():
    # the same source plane wired to several mux inputs (common after
    # CSE) must behave like independent copies
    rng = random.Random(9)
    nwords = 13
    plane = random_plane_words(rng, nwords)
    prev = [plane]
    for w in (4, 8):
        for arity in range(1, MAX_PLANE_SUPPORT + 1):
            table = rng.getrandbits(1 << arity)
            srcs = [0] * arity
            assert plane_wide(table, srcs, prev, nwords, w) == \
                plane_scalar(table, srcs, prev, nwords)
