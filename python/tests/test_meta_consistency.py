"""Guard the python<->rust contract: the artifact metadata emitted by
aot.py must stay consistent with the model's parameter/argument layout,
because the rust runtime assembles HLO argument lists purely from it.

Runs against the checked-in artifacts if present (after `make artifacts`),
otherwise regenerates the spec in-memory for one config.
"""

import json
import os

import pytest

from compile import model as M
from compile.aot import (build_enum, build_infer, build_lut_infer,
                         build_train_step)
from compile.topology import preset, presets

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _flat_names(spec):
    return [n for n, _ in spec]


@pytest.mark.parametrize("top", presets(), ids=lambda t: t.name)
def test_builder_arg_orders_are_derivable(top):
    """Every builder's recorded arg list must follow the spec ordering the
    rust side reconstructs: params, (m, v, stats for train), conn, step
    inputs — with the documented prefixes."""
    fn, ex, args, outs = build_train_step(top, dense=False)
    pn = _flat_names(M.param_spec(top, False))
    sn = _flat_names(M.stats_spec(top))
    cn = _flat_names(M.conn_spec(top))
    want = [f"p:{n}" for n in pn] + [f"m:{n}" for n in pn] \
        + [f"v:{n}" for n in pn] + [f"s:{n}" for n in sn] \
        + [f"c:{n}" for n in cn] \
        + ["x", "y", "lr", "wd", "lam", "skip_scale", "t"]
    assert args == want
    assert len(ex) == len(args)
    assert outs[-1] == "loss"

    fn, ex, args, outs = build_infer(top, use_pallas=False)
    assert len(ex) == len(args)
    assert args[-2:] == ["x", "skip_scale"]
    assert outs == ["codes", "logits"]

    fn, ex, args, outs = build_lut_infer(top)
    assert len(ex) == len(args)
    assert args[-1] == "x"

    for l in range(top.n_layers):
        fn, ex, args, outs = build_enum(top, l)
        assert len(ex) == len(args)
        assert args[-2:] == ["logs_prev", "skip_scale"]
        assert all(a.split(":", 1)[-1].startswith(f"l{l}_")
                   for a in args[:-2]), f"layer {l} arg leak: {args}"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "meta.json")),
    reason="artifacts not built",
)
def test_checked_in_meta_matches_current_model():
    with open(os.path.join(ARTIFACTS, "meta.json")) as f:
        meta = json.load(f)
    for name, cfg in meta["configs"].items():
        top = preset(name)
        assert cfg["topology"]["w"] == top.w, name
        assert cfg["param_spec"] == [
            [n, list(s)] for n, s in M.param_spec(top, False)], name
        assert cfg["stats_spec"] == [
            [n, list(s)] for n, s in M.stats_spec(top)], name
        # every artifact file referenced must exist
        for ename, e in cfg["entries"].items():
            path = os.path.join(ARTIFACTS, e["file"])
            assert os.path.exists(path), f"{name}/{ename} missing {path}"
        # relu flags recorded == recomputed
        assert cfg["relu_flags"] == [bool(b) for b in M.relu_flags(top)], name
