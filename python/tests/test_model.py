"""Model-level tests: shapes, training-step sanity, pallas/ref agreement."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import quant
from compile.topology import Topology, preset

TINY = Topology(
    name="tiny", n_in=12, beta_in=2,
    w=[8, 4, 2], a=[0, 1, 1], F=[3, 2, 2], beta=[2, 2, 4],
    L_sub=2, N=8, S=2, n_classes=2, dataset="synthetic", batch=16,
)
TINY.validate()


def _rand_conn(top, key):
    conn = {}
    for l in range(top.n_layers):
        if top.a[l]:
            conn[f"l{l}_conn"] = jnp.array(top.fixed_connections(l), jnp.int32)
        else:
            key, k = jax.random.split(key)
            conn[f"l{l}_conn"] = jax.random.randint(
                k, (top.w[l], top.F[l]), 0, top.in_width(l), dtype=jnp.int32)
    return conn


def _setup(top, seed=0):
    key = jax.random.PRNGKey(seed)
    params = M.init_params(top, dense=False, key=key)
    stats = M.init_stats(top)
    conn = _rand_conn(top, jax.random.PRNGKey(seed + 1))
    x = jax.random.randint(jax.random.PRNGKey(seed + 2),
                           (top.batch, top.n_in), 0, 1 << top.beta_in,
                           dtype=jnp.int32)
    y = jax.random.randint(jax.random.PRNGKey(seed + 3), (top.batch,), 0,
                           max(top.n_classes, 2), dtype=jnp.int32)
    return params, stats, conn, x, y


def test_forward_shapes():
    params, stats, conn, x, _ = _setup(TINY)
    logits, codes = M.forward(TINY, params, stats, conn, x, 1.0)[:2]
    assert logits.shape == (TINY.batch, TINY.w[-1])
    assert codes.shape == (TINY.batch, TINY.w[-1])
    assert codes.dtype == jnp.int32
    assert int(codes.min()) >= 0 and int(codes.max()) < (1 << TINY.beta[-1])


def test_forward_codes_match_logit_quantization():
    params, stats, conn, x, _ = _setup(TINY)
    logits, codes = M.forward(TINY, params, stats, conn, x, 1.0)[:2]
    s = jnp.exp(params[f"l{TINY.n_layers-1}_logs"])
    want = quant.encode(logits, s, TINY.beta[-1])
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(want))


def test_forward_pallas_matches_ref():
    params, stats, conn, x, _ = _setup(TINY)
    (l1, c1) = M.forward(TINY, params, stats, conn, x, 1.0, use_pallas=False)[:2]
    (l2, c2) = M.forward(TINY, params, stats, conn, x, 1.0, use_pallas=True)[:2]
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5,
                               atol=1e-6)
    # codes may only differ if a value sits exactly on a bin edge; with
    # random float inputs that has probability ~0
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_train_step_decreases_loss():
    params, stats, conn, x, y = _setup(TINY)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}
    losses = []
    step = jax.jit(lambda p, m_, v_, st, t: M.train_step(
        TINY, False, p, m_, v_, st, conn, x, y,
        jnp.float32(0.01), jnp.float32(0.0), jnp.float32(0.0),
        jnp.float32(1.0), t))
    for t in range(1, 41):
        params, m, v, stats, loss = step(params, m, v, stats, jnp.float32(t))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses[::10]


def test_train_step_dense_group_reg_shrinks_groups():
    top = dataclasses.replace(TINY, name="tinyd")
    params = M.init_params(top, dense=True, key=jax.random.PRNGKey(0))
    stats = M.init_stats(top)
    conn = _rand_conn(top, jax.random.PRNGKey(1))
    x = jax.random.randint(jax.random.PRNGKey(2), (top.batch, top.n_in), 0,
                           1 << top.beta_in, dtype=jnp.int32)
    y = jax.random.randint(jax.random.PRNGKey(3), (top.batch,), 0, 2,
                           dtype=jnp.int32)
    m = {k: jnp.zeros_like(p) for k, p in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}
    reg0 = float(M.group_reg(top, params))
    step = jax.jit(lambda p, m_, v_, st, t: M.train_step(
        top, True, p, m_, v_, st, conn, x, y,
        jnp.float32(0.01), jnp.float32(0.0), jnp.float32(3e-3),
        jnp.float32(1.0), t))
    for t in range(1, 31):
        params, m, v, stats, loss = step(params, m, v, stats, jnp.float32(t))
    assert float(M.group_reg(top, params)) < reg0


def test_dense_forward_uses_full_width():
    """Dense variant must see inputs outside the sparse conn set."""
    top = TINY
    params = M.init_params(top, dense=True, key=jax.random.PRNGKey(5))
    stats = M.init_stats(top)
    conn = _rand_conn(top, jax.random.PRNGKey(6))
    x = jnp.zeros((top.batch, top.n_in), jnp.int32)
    x2 = x.at[:, -1].set((1 << top.beta_in) - 1)
    l1, _, _ = M.forward(top, params, stats, conn, x, 1.0, dense=True)
    l2, _, _ = M.forward(top, params, stats, conn, x2, 1.0, dense=True)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_skip_scale_zero_kills_skip_path():
    params, stats, conn, x, _ = _setup(TINY)
    p2 = dict(params)
    for l in range(TINY.n_layers):
        p2[f"l{l}_wskip"] = params[f"l{l}_wskip"] + 7.0
    la, _, _ = M.forward(TINY, params, stats, conn, x, 0.0)
    lb, _, _ = M.forward(TINY, p2, stats, conn, x, 0.0)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_predictions_binary_and_multiclass():
    codes = jnp.array([[1, 5, 3], [7, 0, 2]], dtype=jnp.int32)
    top3 = dataclasses.replace(TINY, n_classes=3, w=[8, 4, 3],
                               a=[0, 1, 0], F=[3, 2, 2])
    np.testing.assert_array_equal(
        np.asarray(M.predictions(top3, codes)), [1, 0])
    topb = preset("nid")
    bc = jnp.array([[0], [1], [2], [3]], dtype=jnp.int32)  # beta=2 -> thr 2
    np.testing.assert_array_equal(
        np.asarray(M.predictions(topb, bc)), [0, 0, 1, 1])


def test_loss_fn_bce_matches_manual():
    topb = preset("nid")
    logits = jnp.array([[0.5], [-1.0], [2.0]], jnp.float32)
    y = jnp.array([1, 0, 1], jnp.int32)
    want = -np.mean([np.log(1 / (1 + np.exp(-0.5))),
                     np.log(1 - 1 / (1 + np.exp(1.0))),
                     np.log(1 / (1 + np.exp(-2.0)))])
    got = float(M.loss_fn(topb, logits, y))
    assert abs(got - want) < 1e-5
