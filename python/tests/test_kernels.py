"""Pallas kernels vs pure-jnp oracles.

The hypothesis-style sweep over shapes/dtypes required by the repro spec is
implemented as parametrized pytest cases over a seeded shape grid (the
image has no hypothesis package); every case asserts allclose against
ref.py, and gradient correctness is checked against jax.grad of the oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.grouped_subnet import grouped_subnet, grouped_subnet_pallas
from compile.kernels.lut_gather import lut_gather_pallas

SHAPES = [
    # (U, B, F, N, Lh, S, final_relu)
    (4, 8, 6, 16, 1, 2, False),
    (6, 16, 3, 8, 1, 2, True),
    (5, 4, 2, 16, 2, 2, False),
    (12, 32, 4, 16, 3, 2, True),
    (1, 128, 6, 64, 1, 2, False),
    (20, 8, 2, 16, 1, 1, False),
]


def _mk_args(key, U, B, F, N, Lh):
    ks = jax.random.split(key, 8)
    return (
        jax.random.normal(ks[0], (U, B, F), jnp.float32),
        jax.random.normal(ks[1], (U, F, N), jnp.float32) * 0.5,
        jax.random.normal(ks[2], (U, N), jnp.float32) * 0.1,
        jax.random.normal(ks[3], (Lh, U, N, N), jnp.float32) * 0.3,
        jax.random.normal(ks[4], (Lh, U, N), jnp.float32) * 0.1,
        jax.random.normal(ks[5], (U, N), jnp.float32) * 0.5,
        jax.random.normal(ks[6], (U,), jnp.float32) * 0.1,
        jax.random.normal(ks[7], (U, F), jnp.float32) * 0.5,
    )


@pytest.mark.parametrize("U,B,F,N,Lh,S,final_relu", SHAPES)
def test_grouped_subnet_matches_ref(U, B, F, N, Lh, S, final_relu):
    args = _mk_args(jax.random.PRNGKey(U * 100 + B), U, B, F, N, Lh)
    want = ref.grouped_subnet_ref(*args, S=S, final_relu=final_relu)
    got = grouped_subnet_pallas(*args, S=S, final_relu=final_relu,
                                skip_scale=1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("skip_scale", [0.0, 1.0])
def test_grouped_subnet_skip_scale(skip_scale):
    U, B, F, N, Lh = 4, 8, 3, 8, 1
    args = _mk_args(jax.random.PRNGKey(0), U, B, F, N, Lh)
    want = ref.grouped_subnet_ref(*args, S=2, final_relu=False,
                                  skip_scale=skip_scale)
    got = grouped_subnet_pallas(*args, S=2, final_relu=False,
                                skip_scale=skip_scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
    if skip_scale == 0.0:
        # skip disabled: perturbing wskip must not change the output
        args2 = args[:7] + (args[7] + 100.0,)
        got2 = grouped_subnet_pallas(*args2, S=2, final_relu=False,
                                     skip_scale=0.0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))


def test_grouped_subnet_custom_vjp_grads():
    U, B, F, N, Lh = 3, 8, 4, 8, 1
    args = _mk_args(jax.random.PRNGKey(7), U, B, F, N, Lh)

    def loss_pallas(*a):
        return jnp.sum(grouped_subnet(*a, 2, False, 1.0) ** 2)

    def loss_ref(*a):
        return jnp.sum(ref.grouped_subnet_ref(*a, S=2, final_relu=False) ** 2)

    g1 = jax.grad(loss_pallas, argnums=tuple(range(8)))(*args)
    g2 = jax.grad(loss_ref, argnums=tuple(range(8)))(*args)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_grouped_subnet_jit_under_jit():
    # The kernel must lower inside jit (this is what aot.py relies on).
    U, B, F, N, Lh = 4, 8, 3, 8, 1
    args = _mk_args(jax.random.PRNGKey(3), U, B, F, N, Lh)
    f = jax.jit(lambda *a: grouped_subnet_pallas(
        *a, S=2, final_relu=False, skip_scale=1.0))
    got = f(*args)
    want = ref.grouped_subnet_ref(*args, S=2, final_relu=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


LUT_SHAPES = [
    # (U, B, F, bits)
    (4, 16, 6, 1),
    (8, 32, 3, 2),
    (5, 8, 2, 4),
    (10, 128, 2, 2),
    (1, 8, 4, 2),
]


@pytest.mark.parametrize("U,B,F,bits", LUT_SHAPES)
def test_lut_gather_matches_ref(U, B, F, bits):
    key = jax.random.PRNGKey(U + B + F + bits)
    T = 1 << (bits * F)
    k1, k2 = jax.random.split(key)
    tables = jax.random.randint(k1, (U, T), 0, 1 << bits, dtype=jnp.int32)
    codes = jax.random.randint(k2, (B, U, F), 0, 1 << bits, dtype=jnp.int32)
    want = ref.lut_gather_ref(tables, codes, bits)
    got = lut_gather_pallas(tables, codes, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pack_codes_bit_layout():
    # input f occupies bits [bits*f, bits*(f+1)): LSB = input 0
    codes = jnp.array([[1, 2, 3]], dtype=jnp.int32)
    assert int(ref.pack_codes(codes, 2)[0]) == 1 + (2 << 2) + (3 << 4)
    codes1 = jnp.array([[1, 0, 1, 1]], dtype=jnp.int32)
    assert int(ref.pack_codes(codes1, 1)[0]) == 0b1101


def test_lut_gather_identity_table():
    # table[u][addr] = addr & mask reproduces the packed low bits
    U, B, F, bits = 3, 8, 2, 2
    T = 1 << (bits * F)
    tables = jnp.broadcast_to(
        (jnp.arange(T, dtype=jnp.int32) & ((1 << bits) - 1))[None], (U, T))
    codes = jax.random.randint(jax.random.PRNGKey(0), (B, U, F), 0, 1 << bits,
                               dtype=jnp.int32)
    got = lut_gather_pallas(tables, codes, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(codes[..., 0]))
