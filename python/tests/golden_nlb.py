"""Deterministic golden `.nlb` models shared by the python and rust suites.

The committed files under ``rust/tests/golden/`` are produced by this
module (``python -m tests.golden_nlb`` from ``python/``, or just rerun
``write_goldens``).  ``test_nlb.py`` asserts the committed bytes still
match what the current writer produces; the rust integration suite loads
the same files, replays the recorded inputs, and must reproduce the
recorded outputs bit-exactly — that pair of tests is the cross-language
format contract.

Everything is seeded ``random.Random`` — no jax, no trained weights —
so regeneration is reproducible anywhere.
"""

from __future__ import annotations

import json
import os
import random
from typing import List, Tuple

from compile import nlb


def _layer(rng: random.Random, prev_w: int, w: int, fan_in: int,
           in_bits: int, out_bits: int) -> nlb.Layer:
    conn = [rng.randrange(prev_w) for _ in range(w * fan_in)]
    entries = 1 << (in_bits * fan_in)
    tables = [rng.randrange(1 << out_bits) for _ in range(w * entries)]
    return nlb.Layer(w=w, fan_in=fan_in, in_bits=in_bits,
                     out_bits=out_bits, conn=conn, tables=tables)


def golden_models() -> List[Tuple[nlb.Netlist, List[List[int]],
                                  List[List[int]]]]:
    """(netlist, input rows, expected output rows) triples."""
    out = []

    rng = random.Random(0x61)
    mix = nlb.Netlist(
        name="golden_mix", n_in=6, in_bits=2,
        layers=[_layer(rng, 6, 5, 2, 2, 2), _layer(rng, 5, 3, 2, 2, 1)])
    out.append(mix)

    rng = random.Random(0x62)
    deep = nlb.Netlist(
        name="golden_deep", n_in=4, in_bits=1,
        layers=[_layer(rng, 4, 6, 3, 1, 2), _layer(rng, 6, 4, 2, 2, 3),
                _layer(rng, 4, 2, 2, 3, 8)])
    out.append(deep)

    triples = []
    for nl in out:
        nl.validate()
        rng = random.Random(nl.content_hash() & 0xFFFF)
        rows = [[rng.randrange(1 << nl.in_bits) for _ in range(nl.n_in)]
                for _ in range(8)]
        triples.append((nl, rows, [nl.eval_one(r) for r in rows]))
    return triples


def write_goldens(out_dir: str) -> List[str]:
    """Write ``<name>.nlb`` per model plus ``golden_io.json``."""
    os.makedirs(out_dir, exist_ok=True)
    written = []
    manifest = []
    for nl, rows, outs in golden_models():
        path = os.path.join(out_dir, f"{nl.name}.nlb")
        nlb.save_nlb(path, nl)
        written.append(path)
        manifest.append({
            "model": nl.name,
            "file": f"{nl.name}.nlb",
            "content_hash": f"{nl.content_hash():016x}",
            "inputs": rows,
            "outputs": outs,
        })
    io_path = os.path.join(out_dir, "golden_io.json")
    with open(io_path, "w") as f:
        json.dump(manifest, f, indent=1)
        f.write("\n")
    written.append(io_path)
    return written


if __name__ == "__main__":
    import sys

    target = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "..", "rust", "tests", "golden")
    for p in write_goldens(os.path.normpath(target)):
        print(p)
