"""Cross-language pin of the retry backoff schedule.

``rust/src/net/client.rs`` computes decorrelated-jitter backoff in
pure u64 µs arithmetic precisely so this mirror can reproduce it
bit-exactly: an inline port of the repo's Xoshiro256** RNG (seeded via
SplitMix64, as in ``rust/src/util/rng.rs``) drives the same schedule
formula, and both suites assert the same five pinned values.  A drift
in either implementation breaks one of the two tests.
"""

M64 = (1 << 64) - 1


def _rotl(x: int, k: int) -> int:
    return ((x << k) | (x >> (64 - k))) & M64


class Xoshiro256StarStar:
    """Port of ``util::Rng`` — Xoshiro256** seeded via SplitMix64."""

    def __init__(self, seed: int):
        sm = seed & M64
        s = []
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & M64
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self) -> int:
        s = self.s
        r = (_rotl((s[1] * 5) & M64, 7) * 9) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return r


def next_backoff_us(rng: Xoshiro256StarStar, base_us: int, cap_us: int,
                    prev_us: int) -> int:
    """Mirror of ``client::next_backoff_us`` (saturating u64 math)."""
    span = max(1, min(prev_us * 3, M64) - base_us) \
        if min(prev_us * 3, M64) > base_us else 1
    return min(cap_us, base_us + rng.next_u64() % span)


def backoff_schedule(seed: int, base_us: int, cap_us: int, n: int):
    rng = Xoshiro256StarStar(seed)
    base = max(1, base_us)
    cap = max(base, cap_us)
    prev = base
    out = []
    for _ in range(n):
        prev = next_backoff_us(rng, base, cap, prev)
        out.append(prev)
    return out


# keep in lockstep with client.rs::backoff_schedule_is_pinned_cross_language
PINNED_BACKOFF_US = [15_407, 42_344, 15_890, 13_804, 23_193]


def test_backoff_schedule_is_pinned_cross_language():
    assert backoff_schedule(0xDECAF, 10_000, 1_000_000, 5) == \
        PINNED_BACKOFF_US


def test_backoff_stays_within_bounds_and_is_deterministic():
    a = backoff_schedule(0xDECAF, 10_000, 1_000_000, 64)
    b = backoff_schedule(0xDECAF, 10_000, 1_000_000, 64)
    assert a == b
    assert all(10_000 <= s <= 1_000_000 for s in a)
    assert max(a) > a[0], "the jitter window never grew"
    assert a != backoff_schedule(0xDECAF + 1, 10_000, 1_000_000, 64)


def test_rng_port_matches_rust_unit_test_property():
    # mirror of util::rng determinism: same seed, same stream
    a = Xoshiro256StarStar(42)
    b = Xoshiro256StarStar(42)
    assert [a.next_u64() for _ in range(100)] == \
        [b.next_u64() for _ in range(100)]
    assert Xoshiro256StarStar(1).next_u64() != \
        Xoshiro256StarStar(2).next_u64()


def test_degenerate_policy_floors_at_one_microsecond():
    assert backoff_schedule(1, 0, 0, 16) == [1] * 16
