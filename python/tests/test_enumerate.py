"""The reproduction's correctness keystone, checked in pure python first:

composing the enumerated truth tables (``enum_layer``) through code-level
lookups (``lut_infer``) must reproduce ``forward``'s output codes
*bit-exactly* — this is what makes the generated FPGA netlist equivalent to
the trained QAT model, and what the rust netlist simulator re-verifies at
the system level.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.topology import Topology, preset

CASES = [
    Topology(name="tiny1", n_in=12, beta_in=2, w=[8, 4, 2], a=[0, 1, 1],
             F=[3, 2, 2], beta=[2, 2, 4], L_sub=2, N=8, S=2, n_classes=2,
             dataset="synthetic", batch=32),
    Topology(name="tiny2", n_in=20, beta_in=1, w=[12, 4, 3], a=[0, 1, 0],
             F=[4, 3, 2], beta=[1, 2, 5], L_sub=3, N=8, S=2, n_classes=3,
             dataset="synthetic", batch=16),
    Topology(name="tiny3", n_in=6, beta_in=3, w=[6, 3, 1], a=[0, 1, 1],
             F=[2, 2, 3], beta=[3, 2, 2], L_sub=2, N=4, S=2, n_classes=1,
             dataset="synthetic", batch=64),
]
for c in CASES:
    c.validate()


def _busy_stats(top, key):
    # non-trivial running stats so the BN path is actually exercised
    stats = {}
    for (name, shape) in M.stats_spec(top):
        key, k = jax.random.split(key)
        if name.endswith("_rv"):
            stats[name] = jax.random.uniform(k, shape, jnp.float32, 0.5, 2.0)
        else:
            stats[name] = jax.random.normal(k, shape, jnp.float32) * 0.3
    return stats


def _rand_conn(top, key):
    conn = {}
    for l in range(top.n_layers):
        if top.a[l]:
            conn[f"l{l}_conn"] = jnp.array(top.fixed_connections(l), jnp.int32)
        else:
            key, k = jax.random.split(key)
            conn[f"l{l}_conn"] = jax.random.randint(
                k, (top.w[l], top.F[l]), 0, top.in_width(l), dtype=jnp.int32)
    return conn


def _enumerate_all(top, params, stats, skip_scale=1.0):
    tables = {}
    for l in range(top.n_layers):
        layer_params = {k: v for k, v in params.items()
                        if k.startswith(f"l{l}_")}
        layer_stats = {k: v for k, v in stats.items()
                       if k.startswith(f"l{l}_")}
        logs_prev = jnp.float32(0.0) if l == 0 else params[f"l{l-1}_logs"]
        tables[f"l{l}_tables"] = M.enum_layer(top, l, layer_params,
                                              layer_stats, logs_prev,
                                              skip_scale)
    return tables


@pytest.mark.parametrize("top", CASES, ids=lambda t: t.name)
@pytest.mark.parametrize("skip_scale", [1.0, 0.0])
def test_lut_composition_bit_exact(top, skip_scale):
    key = jax.random.PRNGKey(hash(top.name) % 2**31)
    params = M.init_params(top, dense=False, key=key)
    stats = _busy_stats(top, key)
    conn = _rand_conn(top, jax.random.PRNGKey(1))
    x = jax.random.randint(jax.random.PRNGKey(2), (top.batch, top.n_in), 0,
                           1 << top.beta_in, dtype=jnp.int32)

    _, want_codes, _ = M.forward(top, params, stats, conn, x, skip_scale)
    tables = _enumerate_all(top, params, stats, skip_scale)
    got = M.lut_infer(top, tables, conn, x, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_codes))
    got_pallas = M.lut_infer(top, tables, conn, x, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(got_pallas),
                                  np.asarray(want_codes))


def test_lut_composition_after_training():
    """Bit-exactness must also hold for *trained* (non-random) weights."""
    top = CASES[0]
    params = M.init_params(top, dense=False, key=jax.random.PRNGKey(0))
    stats = M.init_stats(top)
    conn = _rand_conn(top, jax.random.PRNGKey(1))
    x = jax.random.randint(jax.random.PRNGKey(2), (top.batch, top.n_in), 0,
                           1 << top.beta_in, dtype=jnp.int32)
    y = jax.random.randint(jax.random.PRNGKey(3), (top.batch,), 0, 2,
                           dtype=jnp.int32)
    m = {k: jnp.zeros_like(p) for k, p in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}
    step = jax.jit(lambda p, m_, v_, st, t: M.train_step(
        top, False, p, m_, v_, st, conn, x, y, jnp.float32(0.02),
        jnp.float32(1e-4), jnp.float32(0.0), jnp.float32(1.0), t))
    for t in range(1, 21):
        params, m, v, stats, _ = step(params, m, v, stats, jnp.float32(t))

    _, want_codes, _ = M.forward(top, params, stats, conn, x, 1.0)
    tables = _enumerate_all(top, params, stats)
    got = M.lut_infer(top, tables, conn, x, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_codes))


def test_enum_inputs_bit_layout():
    top = CASES[0]
    codes = np.asarray(M.enum_inputs(top, 1))  # bits=2, F=2 -> T=16
    assert codes.shape == (16, 2)
    for addr in range(16):
        assert codes[addr, 0] == (addr >> 0) & 3
        assert codes[addr, 1] == (addr >> 2) & 3


def test_tables_code_range():
    top = CASES[1]
    params = M.init_params(top, dense=False, key=jax.random.PRNGKey(4))
    tables = _enumerate_all(top, params, M.init_stats(top))
    for l in range(top.n_layers):
        t = np.asarray(tables[f"l{l}_tables"])
        assert t.shape == (top.w[l], top.table_entries(l))
        assert t.min() >= 0 and t.max() < (1 << top.beta[l])
