"""Deterministic golden NLWP frames shared by the python and rust suites.

``rust/tests/golden/golden_frames.bin`` is the concatenation of the v2
frames below, produced by this module (``python -m tests.golden_wire``
from ``python/``, or rerun :func:`write_golden`).  ``test_wire.py``
asserts the committed bytes still match what the current encoder
produces; the rust ``golden_wire_frames_decode_and_reencode`` test
decodes the same bytes into the same frames and re-encodes them
byte-identically — that pair of tests is the cross-language protocol
contract, exactly like the ``.nlb`` goldens.

``golden_frames_v1.bin`` pins the *previous* wire version the same
way: it is the original v1 golden byte stream (the v2 reader must keep
decoding it forever, and the v1 encoder must keep reproducing it).
:func:`golden_frames_v1` is the v1-expressible subset of the old list.

Everything is closed-form (no rng, no trained models) so the two
implementations can construct the identical expected list.
"""

from __future__ import annotations

import os
from typing import List, Tuple

from compile import wire


def golden_frames() -> List[Tuple[int, wire.Message]]:
    """(request id, message) pairs — keep in lockstep with the rust
    test's expected list."""
    return [
        (1, wire.Ping()),
        (2, wire.Pong()),
        (0x0123456789ABCDEF,
         wire.Infer(model="nid", batch=2, n_in=3,
                    codes=[0, 1, -2, 3, 2, 1])),
        # a bigger request with closed-form codes: (i * 7) % 19 - 9
        (4, wire.Infer(model="golden_mix", batch=4, n_in=5,
                       codes=[(i * 7) % 19 - 9 for i in range(20)])),
        # v2: a request carrying a 250 ms deadline budget
        (6, wire.Infer(model="dl", batch=1, n_in=4, codes=[1, 2, 3, 4],
                       deadline_us=250_000)),
        (7, wire.Result(batch=2, out_width=1, codes=[1, -3])),
        (8, wire.Error(code=wire.ERR_OVERLOADED, message="shed")),
        (9, wire.Stats(model="")),
        (10, wire.Stats(model="jsc")),
        (11, wire.StatsResult(json='{"x":1}')),
        (12, wire.Result(batch=3, out_width=0, codes=[])),
        # v2 error codes
        (13, wire.Error(code=wire.ERR_DEADLINE, message="late")),
        (14, wire.Error(code=wire.ERR_CONN_QUOTA, message="greedy")),
    ]


def golden_frames_v1() -> List[Tuple[int, wire.Message]]:
    """The original v1 golden list (no deadlines, no v2 error codes) —
    pinned forever for cross-version compatibility."""
    return [
        (1, wire.Ping()),
        (2, wire.Pong()),
        (0x0123456789ABCDEF,
         wire.Infer(model="nid", batch=2, n_in=3,
                    codes=[0, 1, -2, 3, 2, 1])),
        (4, wire.Infer(model="golden_mix", batch=4, n_in=5,
                       codes=[(i * 7) % 19 - 9 for i in range(20)])),
        (7, wire.Result(batch=2, out_width=1, codes=[1, -3])),
        (8, wire.Error(code=wire.ERR_OVERLOADED, message="shed")),
        (9, wire.Stats(model="")),
        (10, wire.Stats(model="jsc")),
        (11, wire.StatsResult(json='{"x":1}')),
        (12, wire.Result(batch=3, out_width=0, codes=[])),
    ]


def golden_bytes() -> bytes:
    return b"".join(wire.encode_frame(i, m) for i, m in golden_frames())


def golden_bytes_v1() -> bytes:
    return b"".join(wire.encode_frame(i, m, version=1)
                    for i, m in golden_frames_v1())


def write_golden(out_dir: str) -> List[str]:
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for name, data in (("golden_frames.bin", golden_bytes()),
                       ("golden_frames_v1.bin", golden_bytes_v1())):
        path = os.path.join(out_dir, name)
        with open(path, "wb") as f:
            f.write(data)
        paths.append(path)
    return paths


if __name__ == "__main__":
    import sys

    target = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "..", "rust", "tests", "golden")
    for p in write_golden(os.path.normpath(target)):
        print(p)
