"""`.nlb` artifact format tests: canonical round-trips, rejection of
malformed files, session export, and the committed golden files staying
in sync with the writer (the rust integration suite holds the other end
of that contract)."""

import dataclasses
import os
import random
import struct

import pytest

from compile import model as M
from compile import nlb
from compile.topology import Topology

import golden_nlb


def _random_netlist(seed: int) -> nlb.Netlist:
    rng = random.Random(seed)
    return nlb.Netlist(
        name=f"t{seed}", n_in=5, in_bits=2,
        layers=[golden_nlb._layer(rng, 5, 4, 2, 2, 2),
                golden_nlb._layer(rng, 4, 2, 2, 2, 1)])


def test_roundtrip_is_canonical():
    nl = _random_netlist(3)
    data = nlb.write_nlb_bytes(nl)
    back = nlb.read_nlb_bytes(data)
    assert back == nl
    # re-encoding the decoded model is byte-identical
    assert nlb.write_nlb_bytes(back) == data


def test_content_hash_excludes_name():
    nl = _random_netlist(5)
    renamed = dataclasses.replace(nl, name="other")
    assert renamed.content_hash() == nl.content_hash()
    changed = dataclasses.replace(nl, n_in=nl.n_in + 1)
    assert changed.content_hash() != nl.content_hash()


def test_zero_layer_netlist_roundtrips():
    nl = nlb.Netlist(name="empty", n_in=3, in_bits=2, layers=[])
    back = nlb.read_nlb_bytes(nlb.write_nlb_bytes(nl))
    assert back == nl
    assert back.eval_one([1, 2, 3]) == [1, 2, 3]


def test_rejects_truncation_at_every_length():
    data = nlb.write_nlb_bytes(_random_netlist(7))
    for n in range(len(data)):
        with pytest.raises(ValueError):
            nlb.read_nlb_bytes(data[:n])


@pytest.mark.parametrize("patch,needle", [
    ((0, b"X"), "magic"),
    ((4, struct.pack("<H", nlb.NLB_VERSION + 1)), "version"),
    ((6, b"\x80"), "flag"),
    ((8, None), "content hash"),   # None => xor the byte
    ((-1, None), "checksum"),
])
def test_rejects_corrupt_headers(patch, needle):
    data = bytearray(nlb.write_nlb_bytes(_random_netlist(11)))
    off, val = patch
    if val is None:
        data[off] ^= 0x01 if off >= 0 else 0xFF
    else:
        data[off:off + len(val)] = val
    with pytest.raises(ValueError, match=needle):
        nlb.read_nlb_bytes(bytes(data))


def test_reads_v1_files():
    """v1 differs from a plan-free v2 file only in the version field —
    the reader must keep accepting it (back-compat contract with the
    committed v1 fixture on the rust side)."""
    nl = _random_netlist(9)
    data = bytearray(nlb.write_nlb_bytes(nl))
    data[4:6] = struct.pack("<H", 1)
    assert nlb.read_nlb_bytes(bytes(data)) == nl


def test_rejects_trailing_garbage():
    data = nlb.write_nlb_bytes(_random_netlist(13)) + b"\x00"
    with pytest.raises(ValueError):
        nlb.read_nlb_bytes(data)


def test_save_load_roundtrip(tmp_path):
    nl = _random_netlist(17)
    path = str(tmp_path / "model.nlb")
    nlb.save_nlb(path, nl)
    assert nlb.load_nlb(path) == nl


def _tiny_topology() -> Topology:
    top = Topology(
        name="tiny", n_in=4, beta_in=2,
        w=[6, 3], a=[0, 1], F=[2, 2], beta=[2, 2],
        L_sub=1, N=4, S=1, n_classes=3, dataset="jsc_cernbox")
    top.validate()
    return top


def _session_arrays(top: Topology, seed: int):
    """Synthetic (tables, conn) dicts in the trained-session layout."""
    rng = random.Random(seed)
    tables, conn = {}, {}
    for l in range(top.n_layers):
        t = top.table_entries(l)
        tables[f"l{l}_tables"] = [
            [rng.randrange(1 << top.beta[l]) for _ in range(t)]
            for _ in range(top.w[l])]
        if top.a[l]:
            conn[f"l{l}_conn"] = top.fixed_connections(l)
        else:
            conn[f"l{l}_conn"] = [
                [rng.randrange(top.in_width(l)) for _ in range(top.F[l])]
                for _ in range(top.w[l])]
    return tables, conn


def test_from_session_matches_lut_infer():
    """The exported netlist must evaluate exactly like the session it
    came from: nlb.eval_one vs model.lut_infer on the same tables."""
    jnp = pytest.importorskip("jax.numpy")
    top = _tiny_topology()
    tables, conn = _session_arrays(top, 23)
    nl = nlb.from_session(top, tables, conn)
    assert nl.name == top.name
    assert nl.n_in == top.n_in and nl.in_bits == top.beta_in

    rng = random.Random(29)
    rows = [[rng.randrange(1 << top.beta_in) for _ in range(top.n_in)]
            for _ in range(16)]
    jt = {k: jnp.asarray(v, dtype=jnp.int32) for k, v in tables.items()}
    jc = {k: jnp.asarray(v, dtype=jnp.int32) for k, v in conn.items()}
    want = M.lut_infer(top, jt, jc, jnp.asarray(rows, dtype=jnp.int32),
                       use_pallas=False)
    got = [nl.eval_one(r) for r in rows]
    assert got == [list(map(int, row)) for row in want]


def test_from_session_survives_format_roundtrip():
    top = _tiny_topology()
    tables, conn = _session_arrays(top, 31)
    nl = nlb.from_session(top, tables, conn)
    assert nlb.read_nlb_bytes(nlb.write_nlb_bytes(nl)) == nl


GOLDEN_DIR = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "tests", "golden"))


def test_committed_goldens_match_writer():
    """The committed artifacts must be exactly what this writer emits —
    if the format changes, regenerate them (python -m tests.golden_nlb)
    AND bump NLB_VERSION."""
    for nl, rows, outs in golden_nlb.golden_models():
        path = os.path.join(GOLDEN_DIR, f"{nl.name}.nlb")
        with open(path, "rb") as f:
            committed = f.read()
        assert committed == nlb.write_nlb_bytes(nl), nl.name
        assert [nl.eval_one(r) for r in rows] == outs
