"""L2: the NeuraLUT-Assemble model in JAX.

Entry points (all lowered to HLO text by ``aot.py`` and driven from rust):

* ``train_step``        — one AdamW step of the sparse (tree) model.
* ``train_step_dense``  — one AdamW step of the dense variant used by the
                          hardware-aware pruning phase, with the group-lasso
                          regularizer on learned layers.
* ``infer``             — quantized forward; returns output codes + logits.
* ``infer_pallas``      — same forward through the L1 Pallas kernel.
* ``enum_layer``        — truth-table enumeration of one layer's units.
* ``lut_infer``         — full LUT-network inference from truth tables via
                          the L1 ``lut_gather`` Pallas kernel.

Bit-exactness contract (DESIGN.md §3.3): ``infer``, ``enum_layer`` and the
rust netlist simulator all compose; ``infer`` and ``enum_layer`` share the
same jnp unit-forward and the same encode/decode, so composing the
enumerated tables reproduces ``infer``'s output codes exactly.

Parameters are handled as a *flat ordered dict* so the HLO argument order
is deterministic and recorded in ``meta.json`` for the rust runtime.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import quant
from .topology import Topology
from .kernels.ref import grouped_subnet_ref, lut_gather_ref
from .kernels.grouped_subnet import grouped_subnet as grouped_subnet_pallas_vjp
from .kernels.lut_gather import lut_gather_pallas

Params = Dict[str, jnp.ndarray]

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8

BN_EPS = 1e-5
BN_MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# Structure helpers
# ---------------------------------------------------------------------------

def relu_flags(top: Topology) -> List[bool]:
    """Output-activation flags per layer.

    NeuraLUT-Assemble removes the neuron activation everywhere except the
    final layer of each assembled tree (a maximal run ``[learned layer,
    assemble*, ...]``); the network's output layer stays linear so the
    logits are unconstrained.
    """
    n = top.n_layers
    flags = []
    for l in range(n):
        run_end = (l == n - 1) or (top.a[l + 1] == 0)
        flags.append(run_end and l != n - 1)
    return flags


def param_spec(top: Topology, dense: bool) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list of trainable parameters."""
    spec: List[Tuple[str, Tuple[int, ...]]] = []
    Lh = top.L_sub - 1
    assert Lh >= 1, "L_sub must be >= 2"
    for l in range(top.n_layers):
        w = top.w[l]
        fan = top.in_width(l) if (dense and top.a[l] == 0) else top.F[l]
        n = top.N
        spec += [
            (f"l{l}_W0", (w, fan, n)),
            (f"l{l}_b0", (w, n)),
            (f"l{l}_Wh", (Lh, w, n, n)),
            (f"l{l}_bh", (Lh, w, n)),
            (f"l{l}_wout", (w, n)),
            (f"l{l}_bout", (w,)),
            (f"l{l}_wskip", (w, fan)),
            (f"l{l}_gamma", (w,)),   # per-unit batch-norm scale
            (f"l{l}_bnb", (w,)),     # per-unit batch-norm shift
            (f"l{l}_logs", ()),
        ]
    return spec


def stats_spec(top: Topology) -> List[Tuple[str, Tuple[int, ...]]]:
    """Batch-norm running statistics (updated by EMA in train_step, used
    verbatim by infer/enumerate — the Brevitas-style folded BN)."""
    spec: List[Tuple[str, Tuple[int, ...]]] = []
    for l in range(top.n_layers):
        spec += [(f"l{l}_rm", (top.w[l],)), (f"l{l}_rv", (top.w[l],))]
    return spec


def conn_spec(top: Topology) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list of connection-index inputs (int32)."""
    return [(f"l{l}_conn", (top.w[l], top.F[l])) for l in range(top.n_layers)]


def init_params(top: Topology, dense: bool, key) -> Params:
    """He-style init (the rust side re-implements this; kept for pytest)."""
    params: Params = {}
    for name, shape in param_spec(top, dense):
        key, sub = jax.random.split(key)
        if name.endswith("_logs"):
            params[name] = jnp.zeros(shape, jnp.float32)  # scale s = 1.0
        elif name.endswith("_gamma"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_b0", "_bh", "_bout", "_bnb")):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name.endswith("_wskip"):
            fan_in = shape[-1]
            params[name] = jax.random.normal(sub, shape, jnp.float32) \
                * (0.5 / jnp.sqrt(fan_in))
        else:
            fan_in = shape[-2]
            params[name] = jax.random.normal(sub, shape, jnp.float32) \
                * jnp.sqrt(2.0 / fan_in)
    return params


def init_stats(top: Topology) -> Params:
    return {
        name: (jnp.ones(shape, jnp.float32) if name.endswith("_rv")
               else jnp.zeros(shape, jnp.float32))
        for name, shape in stats_spec(top)
    }


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _unit_forward(xin, p, l: int, S: int, final_relu: bool, skip_scale,
                  use_pallas: bool):
    """xin: [U, B, F] -> [U, B] pre-quantization outputs of layer ``l``."""
    args = (xin, p[f"l{l}_W0"], p[f"l{l}_b0"], p[f"l{l}_Wh"], p[f"l{l}_bh"],
            p[f"l{l}_wout"], p[f"l{l}_bout"], p[f"l{l}_wskip"])
    if use_pallas:
        return grouped_subnet_pallas_vjp(*args, S, final_relu, skip_scale)
    return grouped_subnet_ref(*args, S=S, final_relu=final_relu,
                              skip_scale=skip_scale)


def _dense_layer_forward(prev, p, l: int, S: int, final_relu: bool,
                         skip_scale):
    """Dense learned layer: every unit sees the full previous width.

    prev: [B, P] -> [U, B] with W0: [U, P, N], wskip: [U, P].
    """
    h = jnp.einsum("bp,upn->ubn", prev, p[f"l{l}_W0"]) \
        + p[f"l{l}_b0"][:, None, :]
    h = jnp.maximum(h, 0.0)
    hs = {1: h}
    Wh, bh = p[f"l{l}_Wh"], p[f"l{l}_bh"]
    for k in range(Wh.shape[0]):
        pos = k + 2
        h = jnp.einsum("ubn,unm->ubm", h, Wh[k]) + bh[k][:, None, :]
        if pos - S >= 1:
            h = h + hs[pos - S]
        h = jnp.maximum(h, 0.0)
        hs[pos] = h
    out = jnp.einsum("ubn,un->ub", h, p[f"l{l}_wout"]) \
        + p[f"l{l}_bout"][:, None]
    out = out + skip_scale * jnp.einsum("bp,up->ub", prev, p[f"l{l}_wskip"])
    if final_relu:
        out = jnp.maximum(out, 0.0)
    return out


def batch_norm(out, params: Params, stats: Params, l: int, train: bool):
    """Per-unit batch norm on the pre-quantization output (paper §III-B1:
    'each sub-network incorporates batch normalization').

    out: [B, U].  Training mode normalizes with batch statistics and
    returns EMA-updated running stats; eval mode (and enumeration) uses the
    running statistics so the function is per-sample and enumerable.
    """
    gamma = params[f"l{l}_gamma"]
    bnb = params[f"l{l}_bnb"]
    if train:
        mu = jnp.mean(out, axis=0)                      # [U]
        var = jnp.var(out, axis=0)
        new_rm = BN_MOMENTUM * stats[f"l{l}_rm"] + (1 - BN_MOMENTUM) * mu
        new_rv = BN_MOMENTUM * stats[f"l{l}_rv"] + (1 - BN_MOMENTUM) * var
        y = gamma * (out - mu) / jnp.sqrt(var + BN_EPS) + bnb
        return y, {f"l{l}_rm": new_rm, f"l{l}_rv": new_rv}
    y = gamma * (out - stats[f"l{l}_rm"]) \
        / jnp.sqrt(stats[f"l{l}_rv"] + BN_EPS) + bnb
    return y, {}


def forward(top: Topology, params: Params, stats: Params, conn: Params,
            x_codes, skip_scale, dense: bool = False,
            use_pallas: bool = False, train: bool = False):
    """Quantized forward pass.

    Returns (logits [B, w_last], out_codes [B, w_last] int32, new_stats).
    """
    flags = relu_flags(top)
    prev = quant.decode(x_codes, quant.input_scale(), top.beta_in)  # [B, P]
    logits = None
    codes = None
    new_stats: Params = {}
    for l in range(top.n_layers):
        if dense and top.a[l] == 0:
            out = _dense_layer_forward(prev, params, l, top.S, flags[l],
                                       skip_scale)                   # [U, B]
        else:
            idx = conn[f"l{l}_conn"]                                 # [U, F]
            xin = prev[:, idx]                                       # [B,U,F]
            xin = jnp.transpose(xin, (1, 0, 2))                      # [U,B,F]
            out = _unit_forward(xin, params, l, top.S, flags[l],
                                skip_scale, use_pallas)              # [U, B]
        out = out.T                                                  # [B, U]
        out, upd = batch_norm(out, params, stats, l, train)
        new_stats.update(upd)
        s = jnp.exp(params[f"l{l}_logs"])
        if l == top.n_layers - 1:
            logits = out
            codes = quant.encode(out, s, top.beta[l])
        else:
            prev = quant.fake_quant(out, s, top.beta[l])
    return logits, codes, new_stats


# ---------------------------------------------------------------------------
# Loss / regularizer / optimizer
# ---------------------------------------------------------------------------

def loss_fn(top: Topology, logits, y):
    """Cross-entropy (n_classes > 1) or BCE-with-logit (n_classes == 1)."""
    if top.n_classes > 1:
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    z = logits[:, 0]
    yf = y.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0.0) - z * yf + jnp.log1p(jnp.exp(-jnp.abs(z))))


def group_reg(top: Topology, params: Params) -> jnp.ndarray:
    """Hardware-aware group lasso on dense learned layers.

    Group = all first-layer weights (W0 column + skip weight) of one
    (unit, candidate input) pair; the l2-of-group, l1-across-groups norm
    drives whole connections to zero so top-F selection is meaningful.
    """
    reg = jnp.float32(0.0)
    for l in range(top.n_layers):
        if top.a[l] == 0:
            w0 = params[f"l{l}_W0"]        # [U, P, N]
            sk = params[f"l{l}_wskip"]     # [U, P]
            g = jnp.sqrt(jnp.sum(w0 * w0, axis=-1) + sk * sk + 1e-12)
            reg = reg + jnp.sum(g)
    return reg


def train_step(top: Topology, dense: bool, params: Params, m: Params,
               v: Params, stats: Params, conn: Params, x_codes, y, lr, wd,
               lam, skip_scale, t):
    """One AdamW (decoupled weight decay) step; lr follows the SGDR schedule
    computed by the rust coordinator and passed in as a scalar.
    Returns (params', m', v', stats', loss)."""

    def objective(p):
        logits, _, new_stats = forward(top, p, stats, conn, x_codes,
                                       skip_scale, dense=dense, train=True)
        loss = loss_fn(top, logits, y)
        if dense:
            loss = loss + lam * group_reg(top, p)
        return loss, new_stats

    (loss, new_stats), grads = jax.value_and_grad(objective, has_aux=True)(params)
    b1t = jnp.power(ADAM_B1, t)
    b2t = jnp.power(ADAM_B2, t)
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        mk = ADAM_B1 * m[k] + (1.0 - ADAM_B1) * g
        vk = ADAM_B2 * v[k] + (1.0 - ADAM_B2) * g * g
        mhat = mk / (1.0 - b1t)
        vhat = vk / (1.0 - b2t)
        upd = mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        new_p[k] = params[k] - lr * upd - lr * wd * params[k]
        new_m[k] = mk
        new_v[k] = vk
    out_stats = {k: new_stats.get(k, stats[k]) for k in stats}
    return new_p, new_m, new_v, out_stats, loss


# ---------------------------------------------------------------------------
# Enumeration + LUT inference
# ---------------------------------------------------------------------------

def enum_inputs(top: Topology, l: int):
    """All 2^(bits*F) input-code combinations of a unit in layer ``l``.

    Returns int32 [T, F]; input f occupies bits [bits*f, bits*(f+1)) of the
    table address (must match ``ref.pack_codes`` and the rust netlist).
    """
    bits = top.in_bits(l)
    F = top.F[l]
    T = top.table_entries(l)
    addr = jnp.arange(T, dtype=jnp.int32)[:, None]
    shifts = jnp.array([bits * f for f in range(F)], dtype=jnp.int32)
    return (addr >> shifts) & ((1 << bits) - 1)


def enum_layer(top: Topology, l: int, layer_params: Params,
               layer_stats: Params, logs_prev, skip_scale):
    """Truth tables of layer ``l``: int32 [w_l, T].

    ``logs_prev`` is the (trained) log-scale of the producer signals
    (layer l-1's output quantizer, or 0.0 == log 1.0 for the input layer).
    ``layer_stats`` carries the BN running statistics, which at inference
    make each unit a pure per-sample function — hence enumerable.
    """
    flags = relu_flags(top)
    bits = top.in_bits(l)
    s_prev = jnp.exp(logs_prev)
    codes = enum_inputs(top, l)                                  # [T, F]
    x = quant.decode(codes, s_prev, bits)                        # [T, F]
    xin = jnp.broadcast_to(x[None], (top.w[l],) + x.shape)       # [U, T, F]
    out = _unit_forward(xin, layer_params, l, top.S, flags[l],
                        skip_scale, use_pallas=False)            # [U, T]
    out, _ = batch_norm(out.T, layer_params, layer_stats, l, train=False)
    out = out.T
    s = jnp.exp(layer_params[f"l{l}_logs"])
    return quant.encode(out, s, top.beta[l])


def lut_infer(top: Topology, tables: Dict[str, jnp.ndarray], conn: Params,
              x_codes, use_pallas: bool = True):
    """Full LUT-network forward from truth tables (int32 codes end-to-end).

    This is the quantized network *as the FPGA executes it*: pure table
    lookups, no arithmetic.  Output: int32 [B, w_last] codes.
    """
    prev = x_codes                                                # [B, P]
    for l in range(top.n_layers):
        idx = conn[f"l{l}_conn"]                                  # [U, F]
        codes = prev[:, idx]                                      # [B, U, F]
        bits = top.in_bits(l)
        tab = tables[f"l{l}_tables"]
        if use_pallas:
            prev = lut_gather_pallas(tab, codes, bits)
        else:
            prev = lut_gather_ref(tab, codes, bits)
    return prev


def predictions(top: Topology, out_codes):
    """Class predictions from output codes (codes are monotone in value)."""
    if top.n_classes > 1:
        return jnp.argmax(out_codes, axis=-1)
    return (out_codes[:, 0] >= (1 << (top.beta[-1] - 1))).astype(jnp.int32)
