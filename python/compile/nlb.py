"""`.nlb` — the versioned on-disk netlist artifact, python writer/reader.

Byte-for-byte mirror of ``rust/src/netlist/format.rs`` (netlist section;
the optional compiled-plan image is rust-only — a python-exported file
sets no flag bits and the rust server compiles a plan at registration,
or serves it through its persistent plan cache).  The golden-file
integration test on the rust side loads artifacts written by this module
and proves the two implementations agree to the byte.

Wire layout (all integers little-endian)::

    offset  size  field
    0       4     magic "NLBF"
    4       2     version (currently 2)
    6       2     flags (bit 0: compiled-plan image present; never set here)
    8       8     content hash (structural FNV-1a, see Netlist.content_hash)
    16      8     payload length (== file length - 32)
    24      8     payload checksum (FNV-1a over the payload bytes)
    32      ..    payload:
      name            u32 length + UTF-8 bytes
      n_in            u32
      in_bits         u32
      n_layers        u32
      per layer:
        w, fan_in, in_bits, out_bits            4 x u32
        conn     w * fan_in             x u32   (unit-major)
        tables   w * 2^(in_bits*fan_in) x u16   (unit-major)
      padding         (v2+, iff flags bit 0: 0-7 zero bytes so the plan
                       image lands on a file offset that is a multiple
                       of 8 — what makes the rust side's zero-copy
                       mmap load possible; this writer sets no flag
                       bits, so it never emits padding, but the rule is
                       part of the byte contract and mirrored here)
      plan image      (iff flags bit 0 — rust-only section)

The version bumps on any layout change; readers accept exactly the
versions they know and reject the rest.  v2 added the alignment
padding rule above; v1 (identical except unpadded and tagged 1) is
still accepted on both sides via a back-compat read.
"""

from __future__ import annotations

import dataclasses
import os
import struct
from typing import Dict, List, Sequence

from .topology import Topology

NLB_MAGIC = b"NLBF"
NLB_VERSION = 2
NLB_MIN_VERSION = 1      # oldest version the reader still accepts
FLAG_PLAN = 1            # rust-only section; this writer never sets it
MAX_ADDR_BITS = 24       # same cap as rust/src/netlist (2^24 u16 entries)

_MASK64 = (1 << 64) - 1
_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3


def fnv1a(data: bytes) -> int:
    """64-bit FNV-1a over raw bytes (the payload checksum)."""
    h = _FNV_OFFSET
    for b in data:
        h = ((h ^ b) * _FNV_PRIME) & _MASK64
    return h


def _mix(h: int, v: int) -> int:
    return ((h ^ v) * _FNV_PRIME) & _MASK64


@dataclasses.dataclass
class Layer:
    """One L-LUT layer: wiring + enumerated truth tables (unit-major)."""

    w: int
    fan_in: int
    in_bits: int
    out_bits: int
    conn: List[int]      # w * fan_in producer indices
    tables: List[int]    # w * 2^(in_bits*fan_in) output codes (u16)

    @property
    def entries_per_unit(self) -> int:
        return 1 << (self.in_bits * self.fan_in)


@dataclasses.dataclass
class Netlist:
    """The artifact payload — mirrors ``rust::netlist::Netlist``."""

    name: str
    n_in: int
    in_bits: int
    layers: List[Layer]

    @property
    def out_width(self) -> int:
        return self.layers[-1].w if self.layers else self.n_in

    def total_units(self) -> int:
        return sum(l.w for l in self.layers)

    def content_hash(self) -> int:
        """Structural FNV-1a (name excluded) — must match the rust
        ``Netlist::content_hash`` on the same structure."""
        h = _FNV_OFFSET
        h = _mix(h, self.n_in)
        h = _mix(h, self.in_bits)
        h = _mix(h, len(self.layers))
        for layer in self.layers:
            h = _mix(h, layer.w)
            h = _mix(h, layer.fan_in)
            h = _mix(h, layer.in_bits)
            h = _mix(h, layer.out_bits)
            for c in layer.conn:
                h = _mix(h, c)
            h = _mix(h, 0xC0DE5EA1)
            for t in layer.tables:
                h = _mix(h, t)
            h = _mix(h, 0x7AB1E5E9)
        return h

    def validate(self) -> None:
        """Same structural checks as the rust loader (a file we write
        must always load there)."""
        prev_w, prev_bits = self.n_in, self.in_bits
        for l, layer in enumerate(self.layers):
            addr = layer.in_bits * layer.fan_in
            if addr > MAX_ADDR_BITS:
                raise ValueError(
                    f"layer {l}: address width {addr} exceeds cap "
                    f"{MAX_ADDR_BITS}")
            if not 1 <= layer.out_bits <= 16:
                raise ValueError(
                    f"layer {l}: out_bits {layer.out_bits} outside 1..=16")
            if len(layer.conn) != layer.w * layer.fan_in:
                raise ValueError(f"layer {l}: conn len mismatch")
            if len(layer.tables) != layer.w * layer.entries_per_unit:
                raise ValueError(f"layer {l}: tables len mismatch")
            if layer.in_bits != prev_bits:
                raise ValueError(
                    f"layer {l}: in_bits {layer.in_bits} != producer "
                    f"bits {prev_bits}")
            if any(c < 0 or c >= prev_w for c in layer.conn):
                raise ValueError(f"layer {l}: conn index out of range")
            limit = (1 << layer.out_bits) - 1
            if any(t < 0 or t > limit for t in layer.tables):
                raise ValueError(
                    f"layer {l}: table entry exceeds out_bits")
            prev_w, prev_bits = layer.w, layer.out_bits

    def eval_one(self, x: Sequence[int]) -> List[int]:
        """Pure-python reference evaluation (mirrors ``eval_one``)."""
        if len(x) != self.n_in:
            raise ValueError(f"input width {len(x)} != {self.n_in}")
        prev = [c & 0xFFFF for c in x]
        for layer in self.layers:
            t = layer.entries_per_unit
            nxt = []
            for u in range(layer.w):
                addr = 0
                for f in range(layer.fan_in):
                    src = layer.conn[u * layer.fan_in + f]
                    addr |= prev[src] << (layer.in_bits * f)
                nxt.append(layer.tables[u * t + addr])
            prev = nxt
        return prev


def from_session(top: Topology, tables: Dict[str, object],
                 conn: Dict[str, object], name: str = "") -> Netlist:
    """Assemble a :class:`Netlist` from a trained session's enumerated
    truth tables and connection indices.

    ``tables[f"l{l}_tables"]`` is an int array ``[w[l], T_l]`` (the
    output of ``model.enum_layer``); ``conn[f"l{l}_conn"]`` is an int
    array ``[w[l], F[l]]``.  Both are flattened unit-major, exactly the
    order ``lut_infer`` indexes them in.
    """
    layers = []
    for l in range(top.n_layers):
        tab = tables[f"l{l}_tables"]
        idx = conn[f"l{l}_conn"]
        flat_tab = [int(v) for row in tab for v in row]
        flat_conn = [int(v) for row in idx for v in row]
        layers.append(Layer(
            w=top.w[l], fan_in=top.F[l], in_bits=top.in_bits(l),
            out_bits=top.beta[l], conn=flat_conn, tables=flat_tab,
        ))
    nl = Netlist(name=name or top.name, n_in=top.n_in,
                 in_bits=top.beta_in, layers=layers)
    nl.validate()
    return nl


def write_nlb_bytes(nl: Netlist) -> bytes:
    """Serialize to `.nlb` bytes (netlist section only, flags=0)."""
    nl.validate()
    parts = [struct.pack("<I", len(nl.name.encode())),
             nl.name.encode(),
             struct.pack("<III", nl.n_in, nl.in_bits, len(nl.layers))]
    for layer in nl.layers:
        parts.append(struct.pack("<IIII", layer.w, layer.fan_in,
                                 layer.in_bits, layer.out_bits))
        parts.append(struct.pack(f"<{len(layer.conn)}I", *layer.conn))
        parts.append(struct.pack(f"<{len(layer.tables)}H", *layer.tables))
    payload = b"".join(parts)
    # v2 alignment rule: a payload about to grow a plan image is padded
    # with zero bytes to a multiple of 8 first (header is 32 bytes, so
    # the image then starts 8-byte aligned in the file).  This writer
    # never sets FLAG_PLAN, so the padding is always empty here — the
    # computation stays as executable documentation of the contract.
    flags = 0
    if flags & FLAG_PLAN:
        payload += b"\x00" * ((8 - len(payload) % 8) % 8)
    header = NLB_MAGIC + struct.pack(
        "<HHQQQ", NLB_VERSION, flags, nl.content_hash(), len(payload),
        fnv1a(payload))
    return header + payload


def read_nlb_bytes(data: bytes) -> Netlist:
    """Parse and validate `.nlb` bytes (netlist section).

    Rejects files carrying a compiled-plan image: the image encodes
    rust ``ExecPlan`` arenas this side has no use for — re-export
    without a plan, or load it on the rust side.
    """
    if len(data) < 32:
        raise ValueError(f"truncated header: {len(data)} bytes, need 32")
    if data[:4] != NLB_MAGIC:
        raise ValueError(f"bad magic {data[:4]!r} (not an .nlb file)")
    version, flags, content_hash, payload_len, payload_hash = \
        struct.unpack_from("<HHQQQ", data, 4)
    if not NLB_MIN_VERSION <= version <= NLB_VERSION:
        raise ValueError(
            f"unsupported format version {version} (this reader "
            f"handles versions {NLB_MIN_VERSION}..{NLB_VERSION})")
    if flags & ~FLAG_PLAN:
        raise ValueError(f"unknown flag bits {flags & ~FLAG_PLAN:#06x}")
    payload = data[32:]
    if len(payload) != payload_len:
        raise ValueError(
            f"payload is {len(payload)} bytes but the header declares "
            f"{payload_len}")
    if fnv1a(payload) != payload_hash:
        raise ValueError("payload checksum mismatch (file corrupt)")

    pos = 0

    def take(n: int, what: str) -> bytes:
        nonlocal pos
        if len(payload) - pos < n:
            raise ValueError(
                f"truncated: {what} needs {n} bytes at offset {pos}")
        s = payload[pos:pos + n]
        pos += n
        return s

    def u32(what: str) -> int:
        return struct.unpack("<I", take(4, what))[0]

    name = take(u32("name length"), "name").decode("utf-8")
    n_in, in_bits, n_layers = u32("n_in"), u32("in_bits"), u32("layers")
    layers = []
    for l in range(n_layers):
        w, fan_in = u32("w"), u32("fan_in")
        l_bits, out_bits = u32("in_bits"), u32("out_bits")
        addr = l_bits * fan_in
        if addr > MAX_ADDR_BITS:
            raise ValueError(
                f"layer {l}: address width {addr} exceeds cap")
        conn = list(struct.unpack(
            f"<{w * fan_in}I", take(4 * w * fan_in, "conn")))
        n_tab = w * (1 << addr)
        tabs = list(struct.unpack(
            f"<{n_tab}H", take(2 * n_tab, "tables")))
        layers.append(Layer(w=w, fan_in=fan_in, in_bits=l_bits,
                            out_bits=out_bits, conn=conn, tables=tabs))
    nl = Netlist(name=name, n_in=n_in, in_bits=in_bits, layers=layers)
    nl.validate()
    if nl.content_hash() != content_hash:
        raise ValueError(
            f"content hash mismatch: header says {content_hash:016x}, "
            f"payload hashes to {nl.content_hash():016x}")
    if flags & FLAG_PLAN:
        raise ValueError(
            "artifact carries a compiled-plan image (rust-only section)")
    if pos != len(payload):
        raise ValueError(
            f"{len(payload) - pos} trailing bytes after the last section")
    return nl


def save_nlb(path: str, nl: Netlist) -> None:
    """Atomic write (temp + rename), like the rust exporter."""
    data = write_nlb_bytes(nl)
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "wb") as f:
        f.write(data)
    try:
        os.replace(tmp, path)
    except OSError:
        os.unlink(tmp)
        raise


def load_nlb(path: str) -> Netlist:
    with open(path, "rb") as f:
        return read_nlb_bytes(f.read())
