"""Topology definitions for NeuraLUT-Assemble (Table I of the paper).

A model is a sequence of L-LUT layers. Layer ``l`` has:

* ``w[l]``    — number of L-LUT units,
* ``a[l]``    — 1 if this is an *assemble* (tree) layer with fixed strided
                wiring (unit j reads outputs ``[F*j, F*j+F)`` of layer l-1,
                requiring ``w[l-1] == F[l] * w[l]``), 0 if it is a *learned*
                layer whose ``F[l]`` input connections are selected by
                hardware-aware pruning,
* ``F[l]``    — unit fan-in,
* ``beta[l]`` — output bit-width of the layer's units.

``beta_in`` is the bit-width of the (quantized) network inputs.  The unit
inside every L-LUT is a dense sub-network ``F -> N -> ... -> N -> 1`` with
``L_sub`` hidden layers, ReLU on hidden layers, intra-subnet residual
connections every ``S`` layers, and a unit-level linear skip ``x @ w_skip``
added to the output (the paper's tree-level skip path, folded inside the
enumerated truth table).  Only the final layer applies an output activation
at training time; every layer output is fake-quantized to ``beta[l]`` bits.
"""

from __future__ import annotations

import dataclasses
import json
from typing import List


MAX_TABLE_ADDR_BITS = 16  # hard cap so 2^(beta*F) enumeration stays feasible


@dataclasses.dataclass
class Topology:
    """Full architecture description (the paper's Table I parameters)."""

    name: str
    n_in: int                # raw input feature count
    beta_in: int             # input quantization bits
    w: List[int]             # units per layer
    a: List[int]             # assemble flags per layer
    F: List[int]             # fan-ins per layer
    beta: List[int]          # output bits per layer
    L_sub: int               # hidden layers inside each unit ("L" in Table I)
    N: int                   # hidden width inside each unit
    S: int                   # residual step inside each unit
    n_classes: int           # classification arity (1 => binary/BCE head)
    dataset: str             # dataset id understood by the rust side
    batch: int = 128         # AOT-fixed training/inference batch size

    @property
    def n_layers(self) -> int:
        return len(self.w)

    def in_width(self, l: int) -> int:
        """Number of producer signals feeding layer ``l``."""
        return self.n_in if l == 0 else self.w[l - 1]

    def in_bits(self, l: int) -> int:
        """Bit-width of each signal feeding layer ``l``."""
        return self.beta_in if l == 0 else self.beta[l - 1]

    def table_entries(self, l: int) -> int:
        """Number of truth-table entries of each unit in layer ``l``."""
        return 1 << (self.in_bits(l) * self.F[l])

    def validate(self) -> None:
        n = self.n_layers
        if not (len(self.a) == len(self.F) == len(self.beta) == n):
            raise ValueError(f"{self.name}: w/a/F/beta length mismatch")
        if self.w[-1] != (self.n_classes if self.n_classes > 1 else 1):
            raise ValueError(
                f"{self.name}: final layer width {self.w[-1]} != head width")
        for l in range(n):
            if self.a[l]:
                if l == 0:
                    raise ValueError(f"{self.name}: layer 0 cannot assemble")
                if self.w[l - 1] != self.F[l] * self.w[l]:
                    raise ValueError(
                        f"{self.name}: assemble layer {l} needs "
                        f"w[l-1]=F*w[l] ({self.w[l-1]} != {self.F[l]}*{self.w[l]})")
            addr = self.in_bits(l) * self.F[l]
            if addr > MAX_TABLE_ADDR_BITS:
                raise ValueError(
                    f"{self.name}: layer {l} table address {addr} bits "
                    f"exceeds cap {MAX_TABLE_ADDR_BITS}")
            if self.F[l] > self.in_width(l):
                raise ValueError(
                    f"{self.name}: layer {l} fan-in {self.F[l]} exceeds "
                    f"producer width {self.in_width(l)}")
        if self.L_sub < 1 or self.N < 1 or self.S < 1:
            raise ValueError(f"{self.name}: bad L/N/S")

    def fixed_connections(self, l: int) -> List[List[int]]:
        """Strided wiring of an assemble layer (the black edges of Fig. 2)."""
        assert self.a[l] == 1
        f = self.F[l]
        return [[f * j + k for k in range(f)] for j in range(self.w[l])]

    def to_json_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Presets.
#
# "scaled" presets keep every structural property of the paper's Table II
# configurations (assemble-constraint ratios, fan-ins, bit-widths, L/N/S)
# but shrink layer widths so the whole toolflow runs in minutes on one CPU
# core.  NID is small enough that we keep the paper's exact topology.
# Figure 5's three options are built per the paper's description: 16-input
# trees of 4-LUTs (opt1), 16-input trees of 2-LUTs (opt2), and 64-input
# trees of 2-LUTs (opt3), one tree per jet class.
# ---------------------------------------------------------------------------

def presets() -> List[Topology]:
    ps = [
        Topology(
            name="mnist", n_in=784, beta_in=1,
            w=[360, 60, 10], a=[0, 1, 1], F=[6, 6, 6], beta=[1, 1, 6],
            L_sub=2, N=16, S=2, n_classes=10, dataset="mnist", batch=96,
        ),
        Topology(
            name="jsc_cb", n_in=16, beta_in=4,
            w=[80, 40, 20, 10, 5], a=[0, 1, 1, 1, 1],
            F=[2, 2, 2, 2, 2], beta=[4, 4, 4, 4, 8],
            L_sub=2, N=16, S=2, n_classes=5, dataset="jsc_cernbox", batch=128,
        ),
        Topology(
            name="jsc_oml", n_in=16, beta_in=3,
            w=[80, 40, 20, 10, 5], a=[0, 1, 1, 1, 1],
            F=[2, 2, 2, 2, 2], beta=[3, 3, 3, 3, 8],
            L_sub=2, N=16, S=2, n_classes=5, dataset="jsc_openml", batch=128,
        ),
        Topology(  # paper-exact NID topology (Table II)
            name="nid", n_in=593, beta_in=1,
            w=[60, 20, 9, 3, 1], a=[0, 1, 0, 1, 1],
            F=[6, 3, 3, 3, 3], beta=[2, 2, 2, 2, 2],
            L_sub=2, N=16, S=2, n_classes=1, dataset="nid", batch=128,
        ),
        # Fig. 5 option (1): 16-input trees of 4-input LUTs (depth 2).
        Topology(
            name="fig5_opt1", n_in=16, beta_in=2,
            w=[20, 5], a=[0, 1], F=[4, 4], beta=[2, 8],
            L_sub=2, N=16, S=2, n_classes=5, dataset="jsc_cernbox", batch=128,
        ),
        # Fig. 5 option (2): 16-input trees of 2-input LUTs (depth 4).
        Topology(
            name="fig5_opt2", n_in=16, beta_in=2,
            w=[40, 20, 10, 5], a=[0, 1, 1, 1], F=[2, 2, 2, 2],
            beta=[2, 2, 2, 8],
            L_sub=2, N=16, S=2, n_classes=5, dataset="jsc_cernbox", batch=128,
        ),
        # Fig. 5 option (3): 64-input trees of 2-input LUTs (depth 6).
        Topology(
            name="fig5_opt3", n_in=16, beta_in=2,
            w=[160, 80, 40, 20, 10, 5], a=[0, 1, 1, 1, 1, 1],
            F=[2, 2, 2, 2, 2, 2], beta=[2, 2, 2, 2, 2, 8],
            L_sub=2, N=16, S=2, n_classes=5, dataset="jsc_cernbox", batch=128,
        ),
    ]
    for p in ps:
        p.validate()
    return ps


def preset(name: str) -> Topology:
    for p in presets():
        if p.name == name:
            return p
    raise KeyError(name)


if __name__ == "__main__":
    print(json.dumps([p.to_json_dict() for p in presets()], indent=1))
