"""L1 Pallas kernel: grouped tiny-MLP forward over all L-LUT units.

The NeuraLUT-Assemble training/enumeration hot spot is ``U`` independent
sub-networks (one per L-LUT unit) of shape ``F -> N -> ... -> N -> 1``
evaluated over a shared batch.  On a GPU the paper's PyTorch code would run
this as a blocked batched-GEMM across threadblocks; the TPU-shaped mapping
(DESIGN.md §4) tiles over *unit blocks*: each grid step keeps one block of
``GU`` units' weights resident in VMEM and runs the whole subnet for the
full batch tile, feeding the MXU with the ``[F,N]``/``[N,N]`` matmul chain.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO while keeping the same
block structure.  Gradients are provided by a ``custom_vjp`` whose backward
pass differentiates the pure-jnp reference (rematerializing the forward).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import grouped_subnet_ref


def _unit_block(U: int, cap: int = 16) -> int:
    """Largest divisor of ``U`` not exceeding ``cap`` (grid must tile U)."""
    best = 1
    for g in range(1, min(U, cap) + 1):
        if U % g == 0:
            best = g
    return best


def _kernel(x_ref, w0_ref, b0_ref, wh_ref, bh_ref, wout_ref, bout_ref,
            wskip_ref, ss_ref, o_ref, *, S: int, final_relu: bool, Lh: int):
    x = x_ref[...]          # [GU, B, F]
    w0 = w0_ref[...]        # [GU, F, N]
    h = jnp.einsum("ubf,ufn->ubn", x, w0) + b0_ref[...][:, None, :]
    h = jnp.maximum(h, 0.0)
    hs = {1: h}
    for k in range(Lh):
        pos = k + 2
        h = jnp.einsum("ubn,unm->ubm", h, wh_ref[k]) + bh_ref[k][:, None, :]
        if pos - S >= 1:
            h = h + hs[pos - S]
        h = jnp.maximum(h, 0.0)
        hs[pos] = h
    out = jnp.einsum("ubn,un->ub", h, wout_ref[...]) + bout_ref[...][:, None]
    out = out + ss_ref[0] * jnp.einsum("ubf,uf->ub", x, wskip_ref[...])
    if final_relu:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out


def grouped_subnet_pallas(x, W0, b0, Wh, bh, wout, bout, wskip,
                          S: int, final_relu: bool, skip_scale):
    """Pallas forward with the same signature/semantics as the jnp oracle."""
    U, B, F = x.shape
    N = W0.shape[-1]
    Lh = Wh.shape[0]
    GU = _unit_block(U)
    ss = jnp.asarray(skip_scale, jnp.float32).reshape(1)

    grid = (U // GU,)
    return pl.pallas_call(
        functools.partial(_kernel, S=S, final_relu=final_relu, Lh=Lh),
        grid=grid,
        in_specs=[
            pl.BlockSpec((GU, B, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((GU, F, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((GU, N), lambda i: (i, 0)),
            pl.BlockSpec((Lh, GU, N, N), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((Lh, GU, N), lambda i: (0, i, 0)),
            pl.BlockSpec((GU, N), lambda i: (i, 0)),
            pl.BlockSpec((GU,), lambda i: (i,)),
            pl.BlockSpec((GU, F), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((GU, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((U, B), jnp.float32),
        interpret=True,
    )(x, W0, b0, Wh, bh, wout, bout, wskip, ss)


@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9))
def grouped_subnet(x, W0, b0, Wh, bh, wout, bout, wskip, S, final_relu,
                   skip_scale):
    return grouped_subnet_pallas(x, W0, b0, Wh, bh, wout, bout, wskip,
                                 S, final_relu, skip_scale)


def _fwd(x, W0, b0, Wh, bh, wout, bout, wskip, S, final_relu, skip_scale):
    y = grouped_subnet_pallas(x, W0, b0, Wh, bh, wout, bout, wskip,
                              S, final_relu, skip_scale)
    return y, (x, W0, b0, Wh, bh, wout, bout, wskip, skip_scale)


def _bwd(S, final_relu, res, g):
    x, W0, b0, Wh, bh, wout, bout, wskip, skip_scale = res
    # Differentiate the pure-jnp oracle (rematerialized forward): correct by
    # construction and keeps the backward pass out of the Pallas kernel.
    _, vjp = jax.vjp(
        lambda *a: grouped_subnet_ref(*a, S=S, final_relu=final_relu,
                                      skip_scale=skip_scale),
        x, W0, b0, Wh, bh, wout, bout, wskip)
    grads = vjp(g)
    return grads + (jnp.zeros_like(jnp.asarray(skip_scale)),)


grouped_subnet.defvjp(_fwd, _bwd)
