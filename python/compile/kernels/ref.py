"""Pure-jnp oracles for the Pallas kernels.

These are the correctness reference for:

* ``grouped_subnet``  — the batched per-L-LUT tiny-MLP forward (the training
  and enumeration hot spot), and
* ``lut_gather``      — table-lookup inference (the FPGA ROM analogue).

They are also the numerics used inside the *training*, *inference* and
*enumeration* entry points of ``model.py``, so that the enumerated truth
tables compose bit-exactly with the quantized inference path (see
DESIGN.md §3.3).  The Pallas kernels are validated against these in
``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import jax.numpy as jnp


def grouped_subnet_ref(x, W0, b0, Wh, bh, wout, bout, wskip,
                       S: int, final_relu: bool, skip_scale=1.0):
    """Forward pass of ``U`` independent sub-networks over a shared batch.

    Args:
      x:     [U, B, F]  unit inputs (already dequantized).
      W0:    [U, F, N]  first dense layer.
      b0:    [U, N]
      Wh:    [Lh, U, N, N] hidden dense layers (``Lh = L_sub - 1``; may be
             a zero-length leading axis).
      bh:    [Lh, U, N]
      wout:  [U, N]     output projection.
      bout:  [U]
      wskip: [U, F]     unit-level linear skip (the paper's tree-level skip
             path folded inside the L-LUT; disabled when ``skip_scale=0``).
      S:     residual step inside the subnet.
      final_relu: apply ReLU to the pre-quantized output (only the final
             tree layer keeps an activation in NeuraLUT-Assemble).
      skip_scale: scalar multiplier on the skip path (ablation hook).

    Returns:
      [U, B] pre-quantization unit outputs.
    """
    h = jnp.maximum(jnp.einsum("ubf,ufn->ubn", x, W0) + b0[:, None, :], 0.0)
    hs = {1: h}
    for k in range(Wh.shape[0]):
        pos = k + 2  # hidden state index, 1-based
        h = jnp.einsum("ubn,unm->ubm", h, Wh[k]) + bh[k][:, None, :]
        if pos - S >= 1:
            h = h + hs[pos - S]
        h = jnp.maximum(h, 0.0)
        hs[pos] = h
    out = jnp.einsum("ubn,un->ub", h, wout) + bout[:, None]
    out = out + skip_scale * jnp.einsum("ubf,uf->ub", x, wskip)
    if final_relu:
        out = jnp.maximum(out, 0.0)
    return out


def pack_codes(codes, bits: int):
    """[..., F] per-input codes -> [...] packed L-LUT address (LSB = input 0)."""
    F = codes.shape[-1]
    shifts = jnp.array([bits * f for f in range(F)], dtype=jnp.int32)
    return jnp.sum(codes << shifts, axis=-1)


def lut_gather_ref(tables, codes, bits: int):
    """Table-lookup inference for one L-LUT layer.

    Args:
      tables: [U, T] int32 truth tables, ``T = 2^(bits * F)``.
      codes:  [B, U, F] int32 input codes of each unit.
      bits:   per-input code width.

    Returns:
      [B, U] int32 output codes.
    """
    idx = pack_codes(codes, bits)  # [B, U]
    # out[b, u] = tables[u, idx[b, u]]
    return jnp.take_along_axis(tables, idx.T, axis=1).T
