"""L1 Pallas kernel: LUT-network inference by table lookup.

This is the TPU analogue of the FPGA's ROM read: pack each unit's ``F``
input codes into a ``beta*F``-bit address, then gather the truth-table
entry.  One grid step holds a block of units' tables in VMEM and serves the
whole batch — the BlockSpec plays the role that BRAM/LUTRAM partitioning
plays on the FPGA.

Used by the ``lut_infer`` AOT artifact (the request-path executable of the
serving demo) and validated against ``ref.lut_gather_ref`` plus the rust
netlist simulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _unit_block(U: int, cap: int = 32) -> int:
    best = 1
    for g in range(1, min(U, cap) + 1):
        if U % g == 0:
            best = g
    return best


def _kernel(tables_ref, codes_ref, o_ref, *, bits: int):
    codes = codes_ref[...]               # [B, GU, F]
    tables = tables_ref[...]             # [GU, T]
    # Pack the per-input codes into the table address with python-int shift
    # amounts (a jnp constant array would be captured, which Pallas forbids).
    F = codes.shape[-1]
    idx = codes[..., 0]
    for f in range(1, F):
        idx = idx + (codes[..., f] << (bits * f))   # [B, GU]
    o_ref[...] = jnp.take_along_axis(tables, idx.T, axis=1).T


def lut_gather_pallas(tables, codes, bits: int):
    """tables: [U, T] i32, codes: [B, U, F] i32 -> [B, U] i32 output codes."""
    U, T = tables.shape
    B, U2, F = codes.shape
    assert U == U2
    GU = _unit_block(U)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=(U // GU,),
        in_specs=[
            pl.BlockSpec((GU, T), lambda i: (i, 0)),
            pl.BlockSpec((B, GU, F), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((B, GU), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((B, U), jnp.int32),
        interpret=True,
    )(tables, codes)
