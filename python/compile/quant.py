"""Uniform symmetric quantization shared by training, enumeration and the
rust netlist simulator.

Codes are unsigned integers ``c in [0, 2^beta)`` — these are the values that
travel on wires and address L-LUTs.  A code decodes to the *midrise* value

    v(c) = s * ((2c + 1) / 2^beta - 1)            in (-s, s)

and a real ``x`` encodes (with clipping) as

    c(x) = clamp(floor(x / s * 2^(beta-1)) + 2^(beta-1), 0, 2^beta - 1).

``decode(encode(x))`` is the bin-center reconstruction of ``x`` on [-s, s).
For ``beta = 1`` this is the antipodal binary quantizer {-s/2, +s/2}.

The straight-through estimator (``fake_quant``) is the Brevitas-style QAT
quantizer: forward emits the reconstruction, backward passes gradients
through the clip, and the learned scale ``s`` receives gradient through the
reconstruction formula.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def encode(x: jnp.ndarray, s, beta: int) -> jnp.ndarray:
    """Real values -> int32 codes in [0, 2^beta)."""
    half = float(1 << (beta - 1))
    c = jnp.floor(x / s * half) + half
    return jnp.clip(c, 0.0, float((1 << beta) - 1)).astype(jnp.int32)


def decode(c: jnp.ndarray, s, beta: int) -> jnp.ndarray:
    """int32 codes -> midrise reconstruction values."""
    levels = float(1 << beta)
    return s * ((2.0 * c.astype(jnp.float32) + 1.0) / levels - 1.0)


def reconstruct(x: jnp.ndarray, s, beta: int) -> jnp.ndarray:
    """decode(encode(x)) without the integer round-trip (same float result)."""
    return decode(encode(x, s, beta), s, beta)


def fake_quant(x: jnp.ndarray, s, beta: int) -> jnp.ndarray:
    """Straight-through fake quantization with learned scale.

    Forward: midrise reconstruction on [-s, s).  Backward: identity inside
    the clip range w.r.t. ``x`` (zero outside), and the scale ``s`` learns
    through the reconstruction value (PACT/Brevitas-style).
    """
    xc = jnp.clip(x, -s, s * (1.0 - 2.0 ** (-beta)))
    v = reconstruct(x, s, beta)
    # STE: value v in the forward pass, gradient of xc in the backward pass.
    return xc + jax.lax.stop_gradient(v - xc)


def input_scale() -> float:
    """Fixed scale of the network-input quantizer: features live in [-1, 1)."""
    return 1.0
