"""AOT lowering: every model entry point -> HLO *text* artifacts + metadata.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust ``xla`` crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts [--configs mnist,nid,...]

Emits, per config ``c``:
    <out>/<c>/train_step.hlo.txt        sparse-model AdamW step
    <out>/<c>/train_step_dense.hlo.txt  dense variant w/ group lasso
    <out>/<c>/infer.hlo.txt             quantized forward (codes + logits)
    <out>/<c>/infer_pallas.hlo.txt      same through the L1 Pallas kernel
    <out>/<c>/lut_infer.hlo.txt         truth-table inference (Pallas gather)
    <out>/<c>/enum_l<k>.hlo.txt         truth-table enumeration of layer k
and a global ``<out>/meta.json`` describing shapes and argument orders for
the rust runtime.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .topology import Topology, presets

F32 = jnp.float32
I32 = jnp.int32


def _sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_entry(fn, example_args) -> str:
    # keep_unused=True: the rust side passes every recorded argument, so
    # arguments that an entry point ignores (e.g. conn tensors of dense
    # learned layers, lam in the sparse step) must stay in the signature.
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*example_args))


# ---------------------------------------------------------------------------
# Entry-point builders.  Every entry point takes a *flat* argument list whose
# order is recorded in meta.json; params/opt-state/conn dicts are flattened
# in param_spec/conn_spec order.
# ---------------------------------------------------------------------------

def _pack(names, values):
    return dict(zip(names, values))


def build_train_step(top: Topology, dense: bool):
    pnames = [n for n, _ in M.param_spec(top, dense)]
    snames = [n for n, _ in M.stats_spec(top)]
    cnames = [n for n, _ in M.conn_spec(top)]
    np_, ns, nc = len(pnames), len(snames), len(cnames)

    def fn(*args):
        i = 0
        params = _pack(pnames, args[i:i + np_]); i += np_
        m = _pack(pnames, args[i:i + np_]); i += np_
        v = _pack(pnames, args[i:i + np_]); i += np_
        stats = _pack(snames, args[i:i + ns]); i += ns
        conn = _pack(cnames, args[i:i + nc]); i += nc
        x, y, lr, wd, lam, ss, t = args[i:]
        p2, m2, v2, s2, loss = M.train_step(top, dense, params, m, v, stats,
                                            conn, x, y, lr, wd, lam, ss, t)
        return tuple(p2[k] for k in pnames) + tuple(m2[k] for k in pnames) \
            + tuple(v2[k] for k in pnames) + tuple(s2[k] for k in snames) \
            + (loss,)

    pshapes = [s for _, s in M.param_spec(top, dense)]
    sshapes = [s for _, s in M.stats_spec(top)]
    cshapes = [s for _, s in M.conn_spec(top)]
    ex = [_sds(s) for s in pshapes] * 3 \
        + [_sds(s) for s in sshapes] \
        + [_sds(s, I32) for s in cshapes] \
        + [_sds((top.batch, top.n_in), I32), _sds((top.batch,), I32),
           _sds((), F32), _sds((), F32), _sds((), F32), _sds((), F32),
           _sds((), F32)]
    args = [f"p:{n}" for n in pnames] + [f"m:{n}" for n in pnames] \
        + [f"v:{n}" for n in pnames] + [f"s:{n}" for n in snames] \
        + [f"c:{n}" for n in cnames] \
        + ["x", "y", "lr", "wd", "lam", "skip_scale", "t"]
    outs = [f"p:{n}" for n in pnames] + [f"m:{n}" for n in pnames] \
        + [f"v:{n}" for n in pnames] + [f"s:{n}" for n in snames] + ["loss"]
    return fn, ex, args, outs


def build_infer(top: Topology, use_pallas: bool):
    pnames = [n for n, _ in M.param_spec(top, dense=False)]
    snames = [n for n, _ in M.stats_spec(top)]
    cnames = [n for n, _ in M.conn_spec(top)]
    np_, ns, nc = len(pnames), len(snames), len(cnames)

    def fn(*args):
        params = _pack(pnames, args[:np_])
        stats = _pack(snames, args[np_:np_ + ns])
        conn = _pack(cnames, args[np_ + ns:np_ + ns + nc])
        x, ss = args[np_ + ns + nc:]
        logits, codes, _ = M.forward(top, params, stats, conn, x, ss,
                                     use_pallas=use_pallas, train=False)
        return codes, logits

    ex = [_sds(s) for _, s in M.param_spec(top, dense=False)] \
        + [_sds(s) for _, s in M.stats_spec(top)] \
        + [_sds(s, I32) for _, s in M.conn_spec(top)] \
        + [_sds((top.batch, top.n_in), I32), _sds((), F32)]
    args = [f"p:{n}" for n in pnames] + [f"s:{n}" for n in snames] \
        + [f"c:{n}" for n in cnames] + ["x", "skip_scale"]
    return fn, ex, args, ["codes", "logits"]


def build_enum(top: Topology, l: int):
    lnames = [n for n, _ in M.param_spec(top, dense=False)
              if n.startswith(f"l{l}_")]
    lshapes = [s for n, s in M.param_spec(top, dense=False)
               if n.startswith(f"l{l}_")]
    snames = [n for n, _ in M.stats_spec(top) if n.startswith(f"l{l}_")]
    sshapes = [s for n, s in M.stats_spec(top) if n.startswith(f"l{l}_")]

    def fn(*args):
        layer_params = _pack(lnames, args[:len(lnames)])
        layer_stats = _pack(snames,
                            args[len(lnames):len(lnames) + len(snames)])
        logs_prev, ss = args[len(lnames) + len(snames):]
        return (M.enum_layer(top, l, layer_params, layer_stats,
                             logs_prev, ss),)

    ex = [_sds(s) for s in lshapes] + [_sds(s) for s in sshapes] \
        + [_sds((), F32), _sds((), F32)]
    args = [f"p:{n}" for n in lnames] + [f"s:{n}" for n in snames] \
        + ["logs_prev", "skip_scale"]
    return fn, ex, args, ["tables"]


def build_lut_infer(top: Topology):
    tnames = [f"l{l}_tables" for l in range(top.n_layers)]
    tshapes = [(top.w[l], top.table_entries(l)) for l in range(top.n_layers)]
    cnames = [n for n, _ in M.conn_spec(top)]
    nt, nc = len(tnames), len(cnames)

    def fn(*args):
        tables = _pack(tnames, args[:nt])
        conn = _pack(cnames, args[nt:nt + nc])
        x = args[nt + nc]
        return (M.lut_infer(top, tables, conn, x, use_pallas=True),)

    ex = [_sds(s, I32) for s in tshapes] \
        + [_sds(s, I32) for _, s in M.conn_spec(top)] \
        + [_sds((top.batch, top.n_in), I32)]
    args = [f"t:{n}" for n in tnames] + [f"c:{n}" for n in cnames] + ["x"]
    return fn, ex, args, ["codes"]


# ---------------------------------------------------------------------------

def emit_config(top: Topology, out_dir: str) -> dict:
    cfg_dir = os.path.join(out_dir, top.name)
    os.makedirs(cfg_dir, exist_ok=True)
    entries = {}

    def emit(name, built):
        fn, ex, args, outs = built
        t0 = time.time()
        text = lower_entry(fn, ex)
        path = os.path.join(cfg_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries[name] = {"file": f"{top.name}/{name}.hlo.txt",
                         "args": args, "outputs": outs}
        print(f"  {top.name}/{name}: {len(text)} chars "
              f"({time.time() - t0:.1f}s)")

    emit("train_step", build_train_step(top, dense=False))
    emit("train_step_dense", build_train_step(top, dense=True))
    emit("infer", build_infer(top, use_pallas=False))
    emit("infer_pallas", build_infer(top, use_pallas=True))
    emit("lut_infer", build_lut_infer(top))
    for l in range(top.n_layers):
        emit(f"enum_l{l}", build_enum(top, l))

    return {
        "topology": top.to_json_dict(),
        "relu_flags": [bool(b) for b in M.relu_flags(top)],
        "param_spec": [[n, list(s)] for n, s in M.param_spec(top, False)],
        "param_spec_dense": [[n, list(s)] for n, s in M.param_spec(top, True)],
        "stats_spec": [[n, list(s)] for n, s in M.stats_spec(top)],
        "conn_spec": [[n, list(s)] for n, s in M.conn_spec(top)],
        "table_spec": [[f"l{l}_tables", [top.w[l], top.table_entries(l)]]
                       for l in range(top.n_layers)],
        "entries": entries,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="all",
                    help="comma-separated preset names or 'all'")
    ns = ap.parse_args()

    all_tops = presets()
    if ns.configs != "all":
        want = set(ns.configs.split(","))
        all_tops = [t for t in all_tops if t.name in want]
        missing = want - {t.name for t in all_tops}
        if missing:
            raise SystemExit(f"unknown configs: {missing}")

    os.makedirs(ns.out, exist_ok=True)
    meta_path = os.path.join(ns.out, "meta.json")
    meta = {"configs": {}, "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2,
                                    "eps": M.ADAM_EPS}}
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            try:
                meta = json.load(f)
            except Exception:
                pass
        meta.setdefault("configs", {})

    for top in all_tops:
        print(f"config {top.name}")
        meta["configs"][top.name] = emit_config(top, ns.out)

    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
